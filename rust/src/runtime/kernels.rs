//! Pure-Rust CPU kernel primitives for the native backend.
//!
//! Everything here is deterministic, allocation-light, and row-major f32 —
//! the lingua franca of `HostTensor`. Two design rules keep the module
//! honest as a correctness oracle:
//!
//! 1. **Fixed accumulation order.** Every reduction walks a fixed,
//!    shape-derived order per output element — ascending along the
//!    reduction axis for the broadcast-axpy layouts, an 8-lane stripe with
//!    a fixed reduction tree for the dot layout — so the segmented SMLM
//!    path and the per-row reference path perform bit-identical
//!    floating-point work per output element and the golden tests can
//!    compare them tightly.
//! 2. **No hidden state.** Kernels take slices in, write slices out; the
//!    backend owns all buffers.
//!
//! # The unified GEMM entry point
//!
//! All matrix products go through one call, [`gemm`], parameterized by a
//! [`GemmSpec`]: the operand [`Layout`] (`NN`/`NT`/`TN`), the B-operand
//! dtype ([`BData`]: f32 or int8 with per-row scales), and the cache
//! [`Blocking`] parameters. This replaces the former six-function surface
//! (`gemm_nn`/`gemm_nt`/`gemm_tn` and their `par_gemm_*` twins), which
//! would have tripled to eighteen with {scalar, SIMD, int8} variants.
//! Internally the spec dispatches to cache-blocked micro-kernels with two
//! implementations selected at runtime: an AVX2 `f32x8` path
//! (`std::arch`, `is_x86_feature_detected!`) and a portable 8-lane
//! unrolled fallback with the *same* lane structure, so the two are
//! bitwise interchangeable (no FMA contraction on either path).
//!
//! **Determinism contract:** blocking is a pure function of the shape
//! ([`Blocking::for_shape`]) and never reads the thread count; thread
//! parallelism partitions only over independent output rows. Hence
//! `threads = 1` and `threads = N` are bitwise identical on the f32 path,
//! and the int8 path differs from f32 only by the documented quantization
//! tolerance (DESIGN.md §11), never by scheduling.
//!
//! The flagship composite kernel is Segmented Multi-LoRA Multiplication
//! (SMLM, paper Section 3.1): rows of a mixed-adapter batch are sorted
//! into per-adapter segments and each segment issues one gathered
//! two-stage matmul, instead of one pair of rank-r products per row. The
//! sort lives in [`SmlmSegmentation`] — a flat counting sort computed
//! **once per batch** and shared across every layer and LoRA site of a
//! launch — and the segments execute in parallel on the backend's
//! [`ThreadPool`](crate::runtime::parallel::ThreadPool). [`smlm_per_row`]
//! is the naive reference kept as the ablation baseline.

use std::ops::Range;

use crate::runtime::parallel::{SharedSliceMut, ThreadPool};

/// Operand layout of a [`gemm`] call. Dimension names follow the classic
/// convention: the product is always logically `[m×k] · [k×n]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layout {
    /// `y[m×n] += a[m×k] · b[k×n]` — b row-major, `k` rows.
    NN,
    /// `y[m×n] += a[m×k] · bᵀ` — b stored `[n×k]`, `n` rows.
    NT,
    /// `y[k×n] += aᵀ · b` — a stored `[m×k]`, b stored `[m×n]` (`m` rows).
    /// This is the dW shape: columns of the input against gradient rows.
    TN,
}

/// The B operand of a [`gemm`] call: plain f32, or int8 quantized with one
/// f32 scale per *storage row* of B (dequant `w[r][c] ≈ q[r][c] · scale[r]`,
/// fused into the micro-kernels so the quantized pass reads ~4x fewer
/// weight bytes).
#[derive(Debug, Clone, Copy)]
pub enum BData<'a> {
    F32(&'a [f32]),
    Int8 { q: &'a [i8], scales: &'a [f32] },
}

impl BData<'_> {
    fn elems(&self) -> usize {
        match self {
            BData::F32(b) => b.len(),
            BData::Int8 { q, .. } => q.len(),
        }
    }
}

/// Cache-blocking parameters for the [`gemm`] micro-kernels.
///
/// **Determinism:** these are a pure function of the shape (see
/// [`Blocking::for_shape`]) — never derived from the thread count — so the
/// per-element accumulation order is identical at every thread count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Blocking {
    /// Reduction-axis tile (rows of B touched per pass, NN only; the
    /// ascending tile order preserves the naive per-element order).
    pub kc: usize,
    /// Output-column tile (panel width the inner axpy/dot runs over).
    pub nc: usize,
}

impl Blocking {
    /// Shape-derived defaults: a `kc×nc` f32 B-panel of 128×512 ≈ 256 KiB
    /// stays L2-resident and is reused across every output row, which is
    /// where the blocked kernel's bandwidth win over the naive
    /// stream-B-per-row loop comes from.
    pub fn for_shape(_layout: Layout, _m: usize, k: usize, n: usize) -> Self {
        Self { kc: k.clamp(1, 128), nc: n.clamp(1, 512) }
    }
}

/// One fully-described GEMM: output, operands, layout, dtype, blocking.
/// Built by [`GemmSpec::nn`]/[`nt`](GemmSpec::nt)/[`tn`](GemmSpec::tn);
/// executed by [`gemm`].
pub struct GemmSpec<'y, 'a> {
    pub y: &'y mut [f32],
    pub a: &'a [f32],
    pub b: BData<'a>,
    pub layout: Layout,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub blocking: Blocking,
    /// Test hook: skip runtime SIMD detection and run the portable 8-lane
    /// micro-kernels (the bitwise-equality tests diff the two paths).
    pub force_portable: bool,
}

impl<'y, 'a> GemmSpec<'y, 'a> {
    /// Layout-parameterized constructor (the named [`nn`](Self::nn)/
    /// [`nt`](Self::nt)/[`tn`](Self::tn) forms are preferred at call
    /// sites; this one serves layout-generic tests and benches).
    pub fn new(
        layout: Layout,
        y: &'y mut [f32],
        a: &'a [f32],
        b: impl Into<BData<'a>>,
        m: usize,
        k: usize,
        n: usize,
    ) -> Self {
        Self {
            y,
            a,
            b: b.into(),
            layout,
            m,
            k,
            n,
            blocking: Blocking::for_shape(layout, m, k, n),
            force_portable: false,
        }
    }

    /// `y[m×n] += a[m×k] · b[k×n]` (b: f32 slice, or `(q, scales)` int8).
    pub fn nn(
        y: &'y mut [f32],
        a: &'a [f32],
        b: impl Into<BData<'a>>,
        m: usize,
        k: usize,
        n: usize,
    ) -> Self {
        Self::new(Layout::NN, y, a, b, m, k, n)
    }

    /// `y[m×n] += a[m×k] · bᵀ` with b stored `[n×k]`.
    pub fn nt(
        y: &'y mut [f32],
        a: &'a [f32],
        b: impl Into<BData<'a>>,
        m: usize,
        k: usize,
        n: usize,
    ) -> Self {
        Self::new(Layout::NT, y, a, b, m, k, n)
    }

    /// `y[k×n] += aᵀ · b` with a stored `[m×k]`, b stored `[m×n]`.
    pub fn tn(
        y: &'y mut [f32],
        a: &'a [f32],
        b: impl Into<BData<'a>>,
        m: usize,
        k: usize,
        n: usize,
    ) -> Self {
        Self::new(Layout::TN, y, a, b, m, k, n)
    }

    /// Force the portable micro-kernels (test hook).
    pub fn portable(mut self) -> Self {
        self.force_portable = true;
        self
    }
}

impl<'a> From<&'a [f32]> for BData<'a> {
    fn from(b: &'a [f32]) -> Self {
        BData::F32(b)
    }
}

impl<'a> From<(&'a [i8], &'a [f32])> for BData<'a> {
    fn from((q, scales): (&'a [i8], &'a [f32])) -> Self {
        BData::Int8 { q, scales }
    }
}

/// Which micro-kernel implementation a call runs on. `Avx2` is only ever
/// constructed after `is_x86_feature_detected!` succeeds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum MicroPath {
    Portable,
    #[cfg(target_arch = "x86_64")]
    Avx2,
}

#[inline]
fn detect_path(force_portable: bool) -> MicroPath {
    if force_portable {
        return MicroPath::Portable;
    }
    #[cfg(target_arch = "x86_64")]
    {
        // std caches the cpuid probe; this is a load after the first call.
        if is_x86_feature_detected!("avx2") {
            return MicroPath::Avx2;
        }
    }
    MicroPath::Portable
}

/// The unified GEMM entry point (accumulating: `y += …`).
///
/// Row-parallel over the output rows (`m` for `NN`/`NT`, `k` for `TN`)
/// when a pool is supplied; each lane runs the identical serial blocked
/// kernel on its contiguous row block, so per-element accumulation order —
/// and therefore every output bit on the f32 path — is independent of the
/// thread count. Pass `None` when already inside a pool job (e.g. the
/// SMLM segment units): the pool forbids nested dispatch.
pub fn gemm(spec: GemmSpec<'_, '_>, pool: Option<&ThreadPool>) {
    let GemmSpec { y, a, b, layout, m, k, n, blocking, force_portable } = spec;
    debug_assert_eq!(a.len(), m * k);
    let (out_rows, b_rows) = match layout {
        Layout::NN => (m, k),
        Layout::NT => (m, n),
        Layout::TN => (k, m),
    };
    debug_assert_eq!(y.len(), out_rows * n);
    let b_cols = match layout {
        Layout::NN | Layout::TN => n,
        Layout::NT => k,
    };
    debug_assert_eq!(b.elems(), b_rows * b_cols);
    if let BData::Int8 { scales, .. } = b {
        debug_assert_eq!(scales.len(), b_rows);
    }
    if out_rows == 0 || n == 0 {
        return;
    }
    let path = detect_path(force_portable);
    match pool {
        Some(p) if p.threads() > 1 && out_rows > 1 => {
            p.par_rows(y, out_rows, n, |r, ys| {
                run_rows(layout, path, ys, r, a, b, m, k, n, blocking);
            });
        }
        _ => run_rows(layout, path, y, 0..out_rows, a, b, m, k, n, blocking),
    }
}

/// Run one contiguous output-row block `rows` of the full product.
/// `y_block` is exactly that block's storage. Serial; called once per lane.
#[allow(clippy::too_many_arguments)]
fn run_rows(
    layout: Layout,
    path: MicroPath,
    y_block: &mut [f32],
    rows: Range<usize>,
    a: &[f32],
    b: BData<'_>,
    m: usize,
    k: usize,
    n: usize,
    blk: Blocking,
) {
    match layout {
        Layout::NN => {
            let ab = &a[rows.start * k..rows.end * k];
            match b {
                BData::F32(b) => nn_f32(path, y_block, ab, b, rows.len(), k, n, blk),
                BData::Int8 { q, scales } => {
                    nn_i8(path, y_block, ab, q, scales, rows.len(), k, n, blk)
                }
            }
        }
        Layout::NT => {
            let ab = &a[rows.start * k..rows.end * k];
            match b {
                BData::F32(b) => nt_f32(path, y_block, ab, b, rows.len(), k, n, blk),
                BData::Int8 { q, scales } => {
                    nt_i8(path, y_block, ab, q, scales, rows.len(), k, n, blk)
                }
            }
        }
        Layout::TN => match b {
            BData::F32(b) => tn_f32(path, y_block, rows, a, b, m, k, n),
            BData::Int8 { q, scales } => tn_i8(path, y_block, rows, a, q, scales, m, k, n),
        },
    }
}

// ---------------------------------------------------------------------------
// Layout drivers: cache-blocked loops over the micro-kernels. Per-element
// accumulation order is ascending along the reduction axis for NN/TN
// (identical to the naive reference), and the fixed 8-lane stripe for NT.
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn nn_f32(
    path: MicroPath,
    y: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    blk: Blocking,
) {
    // jb/lb tile the B panel so a kc×nc block stays cache-resident and is
    // reused across all m output rows; l still ascends globally per
    // element, so the result is bitwise the naive kernel's.
    for jb in (0..n).step_by(blk.nc) {
        let je = (jb + blk.nc).min(n);
        for lb in (0..k).step_by(blk.kc) {
            let le = (lb + blk.kc).min(k);
            for i in 0..m {
                let yr = &mut y[i * n + jb..i * n + je];
                for l in lb..le {
                    let av = a[i * k + l];
                    axpy(path, yr, &b[l * n + jb..l * n + je], av);
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn nn_i8(
    path: MicroPath,
    y: &mut [f32],
    a: &[f32],
    q: &[i8],
    scales: &[f32],
    m: usize,
    k: usize,
    n: usize,
    blk: Blocking,
) {
    // Dequant is fused as a scalar fold into the broadcast: the row scale
    // multiplies the A element once, then the int8 row streams straight
    // into the f32 accumulator — no dequantized copy of B ever exists.
    for jb in (0..n).step_by(blk.nc) {
        let je = (jb + blk.nc).min(n);
        for lb in (0..k).step_by(blk.kc) {
            let le = (lb + blk.kc).min(k);
            for i in 0..m {
                let yr = &mut y[i * n + jb..i * n + je];
                for l in lb..le {
                    let avs = a[i * k + l] * scales[l];
                    axpy_i8(path, yr, &q[l * n + jb..l * n + je], avs);
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn nt_f32(
    path: MicroPath,
    y: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    blk: Blocking,
) {
    // Tiling j keeps an nc×k panel of B rows hot across all m output rows.
    // Each element is one full-k striped dot (no reduction-axis tiling:
    // that would change the fixed 8-lane tree for no bandwidth win).
    for jb in (0..n).step_by(blk.nc) {
        let je = (jb + blk.nc).min(n);
        for i in 0..m {
            let ar = &a[i * k..(i + 1) * k];
            for j in jb..je {
                y[i * n + j] += dot(path, ar, &b[j * k..(j + 1) * k]);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn nt_i8(
    path: MicroPath,
    y: &mut [f32],
    a: &[f32],
    q: &[i8],
    scales: &[f32],
    m: usize,
    k: usize,
    n: usize,
    blk: Blocking,
) {
    // The per-row scale is hoisted out of the dot (both micro-kernel paths
    // hoist identically, so they stay bitwise interchangeable).
    for jb in (0..n).step_by(blk.nc) {
        let je = (jb + blk.nc).min(n);
        for i in 0..m {
            let ar = &a[i * k..(i + 1) * k];
            for j in jb..je {
                y[i * n + j] += scales[j] * dot_i8(path, ar, &q[j * k..(j + 1) * k]);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn tn_f32(
    path: MicroPath,
    y_block: &mut [f32],
    rows: Range<usize>,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    let _ = k;
    // The reduction axis is the outer i loop (ascending, matching the
    // naive reference bitwise); each pass streams one B row, which stays
    // L1-hot across this lane's l range — the natural blocking.
    for i in 0..m {
        let br = &b[i * n..(i + 1) * n];
        for l in rows.clone() {
            let av = a[i * k + l];
            let lo = (l - rows.start) * n;
            axpy(path, &mut y_block[lo..lo + n], br, av);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn tn_i8(
    path: MicroPath,
    y_block: &mut [f32],
    rows: Range<usize>,
    a: &[f32],
    q: &[i8],
    scales: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    for i in 0..m {
        let qr = &q[i * n..(i + 1) * n];
        for l in rows.clone() {
            let avs = a[i * k + l] * scales[i];
            let lo = (l - rows.start) * n;
            axpy_i8(path, &mut y_block[lo..lo + n], qr, avs);
        }
    }
}

// ---------------------------------------------------------------------------
// Micro-kernels. The portable versions fix the lane structure (8-wide
// stripe, fixed reduction tree, scalar tails); the AVX2 versions perform
// the same per-lane IEEE mul/add (never FMA) on `f32x8` vectors, so the
// two are bitwise interchangeable and runtime dispatch is invisible.
// ---------------------------------------------------------------------------

/// Fixed 8-lane reduction tree shared by both dot implementations.
#[inline(always)]
fn reduce8(acc: [f32; 8], tail: f32) -> f32 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7])) + tail
}

fn axpy_portable(y: &mut [f32], b: &[f32], av: f32) {
    debug_assert_eq!(y.len(), b.len());
    let mut yc = y.chunks_exact_mut(8);
    let mut bc = b.chunks_exact(8);
    for (yy, bb) in (&mut yc).zip(&mut bc) {
        for t in 0..8 {
            yy[t] += av * bb[t];
        }
    }
    for (yy, bb) in yc.into_remainder().iter_mut().zip(bc.remainder()) {
        *yy += av * bb;
    }
}

fn axpy_i8_portable(y: &mut [f32], q: &[i8], avs: f32) {
    debug_assert_eq!(y.len(), q.len());
    let mut yc = y.chunks_exact_mut(8);
    let mut qc = q.chunks_exact(8);
    for (yy, qq) in (&mut yc).zip(&mut qc) {
        for t in 0..8 {
            yy[t] += avs * qq[t] as f32;
        }
    }
    for (yy, qq) in yc.into_remainder().iter_mut().zip(qc.remainder()) {
        *yy += avs * *qq as f32;
    }
}

fn dot_portable(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let mut ac = a.chunks_exact(8);
    let mut bc = b.chunks_exact(8);
    for (aa, bb) in (&mut ac).zip(&mut bc) {
        for t in 0..8 {
            acc[t] += aa[t] * bb[t];
        }
    }
    let mut tail = 0.0f32;
    for (aa, bb) in ac.remainder().iter().zip(bc.remainder()) {
        tail += aa * bb;
    }
    reduce8(acc, tail)
}

fn dot_i8_portable(a: &[f32], q: &[i8]) -> f32 {
    debug_assert_eq!(a.len(), q.len());
    let mut acc = [0.0f32; 8];
    let mut ac = a.chunks_exact(8);
    let mut qc = q.chunks_exact(8);
    for (aa, qq) in (&mut ac).zip(&mut qc) {
        for t in 0..8 {
            acc[t] += aa[t] * qq[t] as f32;
        }
    }
    let mut tail = 0.0f32;
    for (aa, qq) in ac.remainder().iter().zip(qc.remainder()) {
        tail += aa * *qq as f32;
    }
    reduce8(acc, tail)
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! `f32x8` micro-kernels. Every op is a per-lane IEEE mul or add
    //! (`_mm256_mul_ps`/`_mm256_add_ps`, never `fmadd`), the int8→f32
    //! convert is exact, and the dot reduction stores the vector
    //! accumulator and reuses the portable [`reduce8`](super::reduce8)
    //! tree — so each function is bitwise identical to its portable twin.
    //!
    //! Safety: every function requires AVX2; callers go through the
    //! `MicroPath::Avx2` dispatch, which only exists after
    //! `is_x86_feature_detected!("avx2")` succeeded.

    use std::arch::x86_64::*;

    use super::reduce8;

    /// `y += av * b`, 8 lanes at a time.
    ///
    /// # Safety
    ///
    /// Requires AVX2 (guaranteed by the `MicroPath::Avx2` dispatch).
    /// All loads/stores are unaligned (`loadu`/`storeu`) and bounded by
    /// `min(y.len(), b.len())` via `n8 <= n`; callers pass equal-length
    /// slices so the scalar tail's `get_unchecked` stays in bounds.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(y: &mut [f32], b: &[f32], av: f32) {
        let n = y.len();
        let n8 = n & !7;
        let va = _mm256_set1_ps(av);
        let mut j = 0;
        while j < n8 {
            let vy = _mm256_loadu_ps(y.as_ptr().add(j));
            let vb = _mm256_loadu_ps(b.as_ptr().add(j));
            let r = _mm256_add_ps(vy, _mm256_mul_ps(va, vb));
            _mm256_storeu_ps(y.as_mut_ptr().add(j), r);
            j += 8;
        }
        while j < n {
            *y.get_unchecked_mut(j) += av * *b.get_unchecked(j);
            j += 1;
        }
    }

    /// `y += avs * q[j] as f32`, widening int8 lanes exactly.
    ///
    /// # Safety
    ///
    /// Requires AVX2. The 64-bit `_mm_loadl_epi64` reads 8 bytes of `q`
    /// per iteration, bounded by `n8 <= n = y.len()`; callers pass
    /// `q.len() >= y.len()`, so vector and tail accesses are in bounds.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_i8(y: &mut [f32], q: &[i8], avs: f32) {
        let n = y.len();
        let n8 = n & !7;
        let va = _mm256_set1_ps(avs);
        let mut j = 0;
        while j < n8 {
            let vq = _mm_loadl_epi64(q.as_ptr().add(j) as *const __m128i);
            let vf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(vq));
            let vy = _mm256_loadu_ps(y.as_ptr().add(j));
            let r = _mm256_add_ps(vy, _mm256_mul_ps(va, vf));
            _mm256_storeu_ps(y.as_mut_ptr().add(j), r);
            j += 8;
        }
        while j < n {
            *y.get_unchecked_mut(j) += avs * *q.get_unchecked(j) as f32;
            j += 1;
        }
    }

    /// Dot product with the portable `reduce8` tree (bitwise-stable order).
    ///
    /// # Safety
    ///
    /// Requires AVX2. Unaligned loads bounded by `n8 <= n = a.len()`;
    /// callers pass `b.len() >= a.len()`, covering the tail's
    /// `get_unchecked` too.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let n8 = n & !7;
        let mut vacc = _mm256_setzero_ps();
        let mut j = 0;
        while j < n8 {
            let va = _mm256_loadu_ps(a.as_ptr().add(j));
            let vb = _mm256_loadu_ps(b.as_ptr().add(j));
            vacc = _mm256_add_ps(vacc, _mm256_mul_ps(va, vb));
            j += 8;
        }
        let mut acc = [0.0f32; 8];
        _mm256_storeu_ps(acc.as_mut_ptr(), vacc);
        let mut tail = 0.0f32;
        while j < n {
            tail += *a.get_unchecked(j) * *b.get_unchecked(j);
            j += 1;
        }
        reduce8(acc, tail)
    }

    /// Dot of f32 against int8, widening exactly, same `reduce8` order.
    ///
    /// # Safety
    ///
    /// Requires AVX2. Per iteration: 32 bytes of `a` and 8 bytes of `q`,
    /// bounded by `n8 <= n = a.len()`; callers pass `q.len() >= a.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_i8(a: &[f32], q: &[i8]) -> f32 {
        let n = a.len();
        let n8 = n & !7;
        let mut vacc = _mm256_setzero_ps();
        let mut j = 0;
        while j < n8 {
            let va = _mm256_loadu_ps(a.as_ptr().add(j));
            let vq = _mm_loadl_epi64(q.as_ptr().add(j) as *const __m128i);
            let vf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(vq));
            vacc = _mm256_add_ps(vacc, _mm256_mul_ps(va, vf));
            j += 8;
        }
        let mut acc = [0.0f32; 8];
        _mm256_storeu_ps(acc.as_mut_ptr(), vacc);
        let mut tail = 0.0f32;
        while j < n {
            tail += *a.get_unchecked(j) * *q.get_unchecked(j) as f32;
            j += 1;
        }
        reduce8(acc, tail)
    }
}

#[inline]
fn axpy(path: MicroPath, y: &mut [f32], b: &[f32], av: f32) {
    match path {
        MicroPath::Portable => axpy_portable(y, b, av),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx2` is only constructed after runtime detection.
        MicroPath::Avx2 => unsafe { avx2::axpy(y, b, av) },
    }
}

#[inline]
fn axpy_i8(path: MicroPath, y: &mut [f32], q: &[i8], avs: f32) {
    match path {
        MicroPath::Portable => axpy_i8_portable(y, q, avs),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx2` is only constructed after runtime detection.
        MicroPath::Avx2 => unsafe { avx2::axpy_i8(y, q, avs) },
    }
}

#[inline]
fn dot(path: MicroPath, a: &[f32], b: &[f32]) -> f32 {
    match path {
        MicroPath::Portable => dot_portable(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx2` is only constructed after runtime detection.
        MicroPath::Avx2 => unsafe { avx2::dot(a, b) },
    }
}

#[inline]
fn dot_i8(path: MicroPath, a: &[f32], q: &[i8]) -> f32 {
    match path {
        MicroPath::Portable => dot_i8_portable(a, q),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx2` is only constructed after runtime detection.
        MicroPath::Avx2 => unsafe { avx2::dot_i8(a, q) },
    }
}

/// Naive triple-loop reference: the correctness oracle for the blocked
/// kernels and the pre-blocking "scalar" baseline the GEMM bench measures
/// `gemm_speedup_simd` against. `NN`/`TN` share its per-element
/// accumulation order bitwise; `NT` reassociates into the fixed 8-lane
/// stripe (tolerance-tested).
pub fn gemm_reference(
    y: &mut [f32],
    a: &[f32],
    b: BData<'_>,
    layout: Layout,
    m: usize,
    k: usize,
    n: usize,
) {
    let deq = |b: &BData<'_>, row: usize, col: usize, cols: usize| -> f32 {
        match b {
            BData::F32(w) => w[row * cols + col],
            BData::Int8 { q, scales } => q[row * cols + col] as f32 * scales[row],
        }
    };
    match layout {
        Layout::NN => {
            for i in 0..m {
                for l in 0..k {
                    let av = a[i * k + l];
                    for j in 0..n {
                        y[i * n + j] += match b {
                            BData::F32(w) => av * w[l * n + j],
                            // Matches the fused kernel's (a·scale)·q fold.
                            BData::Int8 { q, scales } => (av * scales[l]) * q[l * n + j] as f32,
                        };
                    }
                }
            }
        }
        Layout::NT => {
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    for l in 0..k {
                        acc += a[i * k + l] * deq(&b, j, l, k);
                    }
                    y[i * n + j] += acc;
                }
            }
        }
        Layout::TN => {
            for i in 0..m {
                for l in 0..k {
                    let av = a[i * k + l];
                    for j in 0..n {
                        y[l * n + j] += match b {
                            BData::F32(w) => av * w[i * n + j],
                            BData::Int8 { q, scales } => (av * scales[i]) * q[i * n + j] as f32,
                        };
                    }
                }
            }
        }
    }
}

/// Symmetric per-row int8 quantization: `q[r][c] = round(w[r][c]/scale_r)`
/// with `scale_r = max|w[r]| / 127` (`1.0` for all-zero rows). The
/// quantized-base-weight path (DESIGN.md §11) stores `(q, scales)` per
/// base matrix; dequant is fused into the [`gemm`] micro-kernels.
pub fn quantize_rows_i8(w: &[f32], rows: usize, cols: usize) -> (Vec<i8>, Vec<f32>) {
    debug_assert_eq!(w.len(), rows * cols);
    let mut q = vec![0i8; rows * cols];
    let mut scales = vec![0.0f32; rows];
    for r in 0..rows {
        let row = &w[r * cols..(r + 1) * cols];
        let amax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
        scales[r] = scale;
        for (dst, &v) in q[r * cols..(r + 1) * cols].iter_mut().zip(row) {
            *dst = (v / scale).round().clamp(-127.0, 127.0) as i8;
        }
    }
    (q, scales)
}

/// RMSNorm: out_i = x_i · w_i / sqrt(mean(x²) + eps). Returns the inverse
/// RMS (the backward pass reuses it).
pub fn rmsnorm(out: &mut [f32], x: &[f32], w: &[f32], eps: f32) -> f32 {
    debug_assert_eq!(out.len(), x.len());
    debug_assert_eq!(w.len(), x.len());
    let mut ms = 0.0f32;
    for &v in x {
        ms += v * v;
    }
    let inv_rms = 1.0 / (ms / x.len() as f32 + eps).sqrt();
    for ((o, &xv), &wv) in out.iter_mut().zip(x).zip(w) {
        *o = xv * wv * inv_rms;
    }
    inv_rms
}

/// RMSNorm backward: given dy, the stashed input x and inv_rms, accumulate
/// dx. (Weight gradients are never needed — base weights are frozen.)
pub fn rmsnorm_backward(dx: &mut [f32], dy: &[f32], x: &[f32], w: &[f32], inv_rms: f32) {
    let d = x.len() as f32;
    let mut dot = 0.0f32;
    for i in 0..x.len() {
        dot += dy[i] * w[i] * x[i];
    }
    let c = dot * inv_rms * inv_rms * inv_rms / d;
    for i in 0..x.len() {
        dx[i] += dy[i] * w[i] * inv_rms - x[i] * c;
    }
}

/// Rotary position embedding over one row of `heads × head_dim`, half-dim
/// (Llama-style) rotation at absolute position `pos`. `dir` = 1.0 applies
/// RoPE; `dir` = -1.0 inverts it (the backward pass: rotation is
/// orthonormal, so the inverse is the transpose = negated angle).
///
/// One transcendental `powf` per call (the per-dim frequencies form a
/// geometric series, accumulated in f64): this sits on the per-token
/// per-layer hot path.
pub fn rope(row: &mut [f32], heads: usize, head_dim: usize, pos: usize, theta: f64, dir: f64) {
    debug_assert_eq!(row.len(), heads * head_dim);
    let half = head_dim / 2;
    let step = theta.powf(-2.0 / head_dim as f64);
    let mut freq = 1.0f64;
    for i in 0..half {
        let ang = dir * pos as f64 * freq;
        let (sin, cos) = (ang.sin() as f32, ang.cos() as f32);
        for h in 0..heads {
            let base = h * head_dim;
            let (a, b) = (row[base + i], row[base + half + i]);
            row[base + i] = a * cos - b * sin;
            row[base + half + i] = a * sin + b * cos;
        }
        freq *= step;
    }
}

/// Numerically stable in-place softmax over `x`.
pub fn softmax_inplace(x: &mut [f32]) {
    let mut mx = f32::NEG_INFINITY;
    for &v in x.iter() {
        if v > mx {
            mx = v;
        }
    }
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// SiLU: x · sigmoid(x).
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// d/dx SiLU(x) = sigmoid(x) · (1 + x · (1 − sigmoid(x))).
pub fn silu_grad(x: f32) -> f32 {
    let s = 1.0 / (1.0 + (-x).exp());
    s * (1.0 + x * (1.0 - s))
}

/// A borrowed view over one LoRA site's stacked bank.
#[derive(Debug, Clone, Copy)]
pub struct LoraBankView<'a> {
    /// `[slots, din, r]` — the A factors, one block per bank slot.
    pub a: &'a [f32],
    /// `[slots, r, dout]` — the B factors.
    pub b: &'a [f32],
    /// `[slots]` — per-slot scaling (alpha/r, or the dynamic override).
    pub scaling: &'a [f32],
    pub rank: usize,
    pub din: usize,
    pub dout: usize,
}

impl<'a> LoraBankView<'a> {
    pub fn slots(&self) -> usize {
        self.scaling.len()
    }

    fn a_slot(&self, s: usize) -> &'a [f32] {
        let n = self.din * self.rank;
        &self.a[s * n..(s + 1) * n]
    }

    fn b_slot(&self, s: usize) -> &'a [f32] {
        let n = self.rank * self.dout;
        &self.b[s * n..(s + 1) * n]
    }
}

/// The per-batch row sort behind [`smlm_segmented`]: a flat, stable
/// counting sort of adapter-routed rows into per-slot segments.
///
/// Computed **once per launch** from the batch's per-row adapter ids and
/// shared across every layer and LoRA site (the segments depend only on
/// the routing, never on the weights) — hoisting what used to be a
/// `Vec<Vec<usize>>` rebuild inside every kernel call.
#[derive(Debug, Clone)]
pub struct SmlmSegmentation {
    /// Adapter-routed row indices, grouped by slot; batch order inside a
    /// group (stability fixes the accumulation order).
    order: Vec<usize>,
    /// `[slots + 1]` prefix offsets into `order`.
    start: Vec<usize>,
    /// Slots with at least one routed row (precomputed here so the
    /// per-site kernel calls allocate nothing).
    busy: Vec<usize>,
}

impl SmlmSegmentation {
    /// Counting-sort `adapters` (one id per row, `-1` = base-only) into
    /// per-slot segments.
    pub fn compute(adapters: &[i32], slots: usize) -> Self {
        let mut start = vec![0usize; slots + 1];
        for &a in adapters {
            if a >= 0 {
                debug_assert!((a as usize) < slots, "adapter {a} out of bank range");
                start[a as usize + 1] += 1;
            }
        }
        for s in 0..slots {
            start[s + 1] += start[s];
        }
        let mut cursor = start[..slots].to_vec();
        let mut order = vec![0usize; start[slots]];
        for (i, &a) in adapters.iter().enumerate() {
            if a >= 0 {
                order[cursor[a as usize]] = i;
                cursor[a as usize] += 1;
            }
        }
        let busy = (0..slots).filter(|&s| start[s + 1] > start[s]).collect();
        Self { order, start, busy }
    }

    pub fn slots(&self) -> usize {
        self.start.len() - 1
    }

    /// Row indices routed to slot `s`, in batch order.
    pub fn rows(&self, s: usize) -> &[usize] {
        &self.order[self.start[s]..self.start[s + 1]]
    }

    /// Total adapter-routed rows (base-only rows excluded).
    pub fn routed_rows(&self) -> usize {
        self.order.len()
    }

    /// Slots with at least one routed row (precomputed, allocation-free).
    pub fn busy_slots(&self) -> &[usize] {
        &self.busy
    }
}

/// One work unit's gathered two-stage product over `rows` (a segment or a
/// row block of one): gather → `x·A_s` → `·B_s` → scatter-accumulate with
/// the slot scaling. `xs`/`mid`/`ys` are caller-provided scratch (reused
/// across the units on one lane). Each output row's math involves only
/// that row, so how rows are blocked never changes a bit of output. Runs
/// inside a pool job, so its [`gemm`] calls pass no pool (nested dispatch
/// is forbidden); the unit itself is the parallelism.
///
/// # Safety
///
/// `y.slice` is touched only at `rows`; the caller must guarantee no
/// other concurrent user writes those rows.
unsafe fn smlm_unit(
    x: &[f32],
    rows: &[usize],
    s: usize,
    bank: &LoraBankView,
    y: &SharedSliceMut<f32>,
    xs: &mut Vec<f32>,
    mid: &mut Vec<f32>,
    ys: &mut Vec<f32>,
) {
    let (din, dout, r) = (bank.din, bank.dout, bank.rank);
    let m = rows.len();
    xs.clear();
    xs.reserve(m * din);
    for &i in rows {
        xs.extend_from_slice(&x[i * din..(i + 1) * din]);
    }
    mid.clear();
    mid.resize(m * r, 0.0);
    gemm(GemmSpec::nn(mid.as_mut_slice(), xs, bank.a_slot(s), m, din, r), None);
    ys.clear();
    ys.resize(m * dout, 0.0);
    gemm(GemmSpec::nn(ys.as_mut_slice(), mid, bank.b_slot(s), m, r, dout), None);
    let scale = bank.scaling[s];
    for (seg_i, &i) in rows.iter().enumerate() {
        let src = &ys[seg_i * dout..(seg_i + 1) * dout];
        let dst = y.slice(i * dout, dout);
        for (d, v) in dst.iter_mut().zip(src) {
            *d += scale * v;
        }
    }
}

/// Segmented Multi-LoRA Multiplication: `y[i] += scale_s · (x[i]·A_s)·B_s`
/// for each row `i` routed to slot `s` by `seg`; base-only rows are
/// untouched.
///
/// Each segment gathers its rows once and issues ONE two-stage matmul, so
/// the number of rank-r products scales with the number of *distinct
/// adapters in the batch*, not with the batch size — the paper's answer to
/// the per-row adapter loop that S-LoRA's bgmv kernels also attack.
///
/// Parallelism is partition-only and therefore bitwise thread-count
/// invariant: busy segments are cut into row-block work units no larger
/// than `ceil(routed_rows / threads)` (so one hot adapter cannot pin a
/// single lane), and lanes take contiguous row-weighted runs of units.
/// Unit boundaries depend on the lane count, but every output row's math
/// involves only that row, so blocking never changes a bit of output.
pub fn smlm_segmented(
    pool: &ThreadPool,
    x: &[f32],
    seg: &SmlmSegmentation,
    bank: &LoraBankView,
    y: &mut [f32],
) {
    let (din, dout) = (bank.din, bank.dout);
    debug_assert_eq!(seg.slots(), bank.slots());
    debug_assert_eq!(x.len() * dout, y.len() * din);
    let busy = seg.busy_slots();
    if busy.is_empty() {
        return;
    }
    // Remaining per-call allocations are bounded by the number of busy
    // segments and lanes (work-unit list, gather/product scratch), never
    // by rows.
    let total = seg.routed_rows();
    let block = total.div_ceil(pool.threads());
    // (slot, row range within the segment) work units.
    let mut units: Vec<(usize, usize, usize)> = Vec::new();
    for &s in busy {
        let m = seg.rows(s).len();
        let mut r0 = 0;
        while r0 < m {
            let r1 = (r0 + block).min(m);
            units.push((s, r0, r1));
            r0 = r1;
        }
    }
    // Row-weighted contiguous cuts over the units (prefix-sum partition
    // points) keep lane loads balanced even when unit sizes are ragged.
    let mut prefix = Vec::with_capacity(units.len() + 1);
    prefix.push(0usize);
    for &(_, r0, r1) in &units {
        prefix.push(prefix.last().unwrap() + (r1 - r0));
    }

    let shared = SharedSliceMut::new(y);
    pool.par_partition_weighted(&prefix, |range| {
        // Per-lane scratch, reused across this lane's units.
        let (mut xs, mut mid, mut ys) = (Vec::new(), Vec::new(), Vec::new());
        for &(s, r0, r1) in &units[range] {
            let rows = &seg.rows(s)[r0..r1];
            // SAFETY: units own disjoint row sets and each unit is
            // processed by exactly one lane, so concurrent lanes never
            // write overlapping `y` rows.
            unsafe {
                smlm_unit(x, rows, s, bank, &shared, &mut xs, &mut mid, &mut ys);
            }
        }
    });
}

/// Per-row reference for [`smlm_segmented`]: one pair of rank-r products
/// per row. Kept as the correctness oracle and the ablation baseline the
/// kernel bench sweeps against.
pub fn smlm_per_row(x: &[f32], adapters: &[i32], bank: &LoraBankView, y: &mut [f32]) {
    let (din, dout, r) = (bank.din, bank.dout, bank.rank);
    debug_assert_eq!(x.len(), adapters.len() * din);
    debug_assert_eq!(y.len(), adapters.len() * dout);
    let mut mid = vec![0.0f32; r];
    let mut row = vec![0.0f32; dout];
    for (i, &a) in adapters.iter().enumerate() {
        if a < 0 {
            continue;
        }
        let s = a as usize;
        let xr = &x[i * din..(i + 1) * din];
        mid.iter_mut().for_each(|v| *v = 0.0);
        gemm(GemmSpec::nn(mid.as_mut_slice(), xr, bank.a_slot(s), 1, din, r), None);
        row.iter_mut().for_each(|v| *v = 0.0);
        gemm(GemmSpec::nn(row.as_mut_slice(), &mid, bank.b_slot(s), 1, r, dout), None);
        let scale = bank.scaling[s];
        let dst = &mut y[i * dout..(i + 1) * dout];
        for (d, v) in dst.iter_mut().zip(&row) {
            *d += scale * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * scale).collect()
    }

    #[test]
    fn gemm_nn_matches_manual() {
        // [2x3] · [3x2]
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        let mut y = vec![0.0; 4];
        gemm(GemmSpec::nn(&mut y, &a, b.as_slice(), 2, 3, 2), None);
        assert_eq!(y, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn gemm_transposes_agree() {
        let mut rng = Rng::seed_from_u64(1);
        let (m, k, n) = (3, 5, 4);
        let a = randv(&mut rng, m * k, 1.0);
        let b = randv(&mut rng, k * n, 1.0);
        let mut y = vec![0.0; m * n];
        gemm(GemmSpec::nn(&mut y, &a, b.as_slice(), m, k, n), None);

        // nt: store b transposed [n×k], must reproduce y.
        let mut bt = vec![0.0; n * k];
        for i in 0..k {
            for j in 0..n {
                bt[j * k + i] = b[i * n + j];
            }
        }
        let mut y2 = vec![0.0; m * n];
        gemm(GemmSpec::nt(&mut y2, &a, bt.as_slice(), m, k, n), None);
        for (p, q) in y.iter().zip(&y2) {
            assert!((p - q).abs() < 1e-5);
        }

        // tn: store a transposed [k×m] as the "a" operand with m/k swapped.
        let mut at = vec![0.0; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let mut y3 = vec![0.0; m * n];
        gemm(GemmSpec::tn(&mut y3, &at, b.as_slice(), k, m, n), None);
        for (p, q) in y.iter().zip(&y3) {
            assert!((p - q).abs() < 1e-5);
        }
    }

    #[test]
    fn blocked_gemm_matches_naive_reference() {
        // Shapes chosen to exercise tile remainders (not multiples of the
        // 8-lane stripe or of kc/nc). NN and TN share the naive kernel's
        // per-element accumulation order exactly → bitwise; NT
        // reassociates into the fixed 8-lane stripe → tolerance.
        let mut rng = Rng::seed_from_u64(23);
        let (m, k, n) = (7, 19, 13);
        let a = randv(&mut rng, m * k, 1.0);
        for layout in [Layout::NN, Layout::NT, Layout::TN] {
            let (b_rows, b_cols, y_len) = match layout {
                Layout::NN => (k, n, m * n),
                Layout::NT => (n, k, m * n),
                Layout::TN => (m, n, k * n),
            };
            let b = randv(&mut rng, b_rows * b_cols, 1.0);
            let y0 = randv(&mut rng, y_len, 1.0);
            let mut y_ref = y0.clone();
            gemm_reference(&mut y_ref, &a, BData::F32(&b), layout, m, k, n);
            let mut y = y0.clone();
            gemm(GemmSpec::new(layout, &mut y, &a, b.as_slice(), m, k, n), None);
            for (i, (p, q)) in y.iter().zip(&y_ref).enumerate() {
                match layout {
                    Layout::NN | Layout::TN => assert_eq!(
                        p.to_bits(),
                        q.to_bits(),
                        "{layout:?} elem {i}: blocked {p} vs naive {q}"
                    ),
                    Layout::NT => {
                        assert!((p - q).abs() < 1e-4, "NT elem {i}: {p} vs {q}")
                    }
                }
            }
        }
    }

    #[test]
    fn simd_and_portable_paths_are_bitwise_identical_per_layout() {
        // On AVX2 hosts this diffs the `f32x8` kernels against the 8-lane
        // portable fallback; elsewhere both runs take the portable path
        // and the assertion is trivially true (documented in DESIGN.md
        // §11 — the contract is "dispatch is invisible", which only an
        // AVX2 host can falsify).
        let mut rng = Rng::seed_from_u64(29);
        let (m, k, n) = (6, 21, 17);
        let a = randv(&mut rng, m * k, 1.0);
        for layout in [Layout::NN, Layout::NT, Layout::TN] {
            let (b_rows, b_cols, y_len) = match layout {
                Layout::NN => (k, n, m * n),
                Layout::NT => (n, k, m * n),
                Layout::TN => (m, n, k * n),
            };
            let b = randv(&mut rng, b_rows * b_cols, 1.0);
            let (q, scales) = quantize_rows_i8(&b, b_rows, b_cols);
            let qb = BData::Int8 { q: &q, scales: &scales };
            let y0 = randv(&mut rng, y_len, 1.0);
            // f32 and int8 dtypes both honor the bitwise contract.
            let mut y_auto = y0.clone();
            gemm(GemmSpec::new(layout, &mut y_auto, &a, b.as_slice(), m, k, n), None);
            let mut y_port = y0.clone();
            gemm(GemmSpec::new(layout, &mut y_port, &a, b.as_slice(), m, k, n).portable(), None);
            let mut yq_auto = y0.clone();
            gemm(GemmSpec::new(layout, &mut yq_auto, &a, qb, m, k, n), None);
            let mut yq_port = y0.clone();
            gemm(GemmSpec::new(layout, &mut yq_port, &a, qb, m, k, n).portable(), None);
            for (i, (p, s)) in y_auto.iter().zip(&y_port).enumerate() {
                assert_eq!(p.to_bits(), s.to_bits(), "{layout:?} f32 elem {i}: {p} vs {s}");
            }
            for (i, (p, s)) in yq_auto.iter().zip(&yq_port).enumerate() {
                assert_eq!(p.to_bits(), s.to_bits(), "{layout:?} int8 elem {i}: {p} vs {s}");
            }
        }
    }

    #[test]
    fn gemm_is_bitwise_thread_count_invariant() {
        // The blocked path at t ∈ {1,2,4,8} vs serial, every layout.
        // Blocking comes from the shape alone, so lanes only change which
        // rows a thread computes, never any element's accumulation order.
        let mut rng = Rng::seed_from_u64(31);
        let (m, k, n) = (13, 9, 11);
        let a = randv(&mut rng, m * k, 1.0);
        for layout in [Layout::NN, Layout::NT, Layout::TN] {
            let (b_rows, b_cols, y_len) = match layout {
                Layout::NN => (k, n, m * n),
                Layout::NT => (n, k, m * n),
                Layout::TN => (m, n, k * n),
            };
            let b = randv(&mut rng, b_rows * b_cols, 1.0);
            let y0 = randv(&mut rng, y_len, 1.0);
            let mut y_serial = y0.clone();
            gemm(GemmSpec::new(layout, &mut y_serial, &a, b.as_slice(), m, k, n), None);
            for threads in [1usize, 2, 4, 8] {
                let pool = ThreadPool::new(threads);
                let mut y_par = y0.clone();
                gemm(GemmSpec::new(layout, &mut y_par, &a, b.as_slice(), m, k, n), Some(&pool));
                for (i, (p, q)) in y_serial.iter().zip(&y_par).enumerate() {
                    assert_eq!(
                        p.to_bits(),
                        q.to_bits(),
                        "{layout:?} elem {i}: serial {p} vs threads={threads} {q}"
                    );
                }
            }
        }
    }

    #[test]
    fn quantize_rows_i8_bounds_per_element_error() {
        let mut rng = Rng::seed_from_u64(37);
        let (rows, cols) = (5, 33);
        let mut w = randv(&mut rng, rows * cols, 0.7);
        // Exercise the all-zero-row guard too.
        for v in w[2 * cols..3 * cols].iter_mut() {
            *v = 0.0;
        }
        let (q, scales) = quantize_rows_i8(&w, rows, cols);
        assert_eq!(scales[2], 1.0);
        for r in 0..rows {
            for c in 0..cols {
                let deq = q[r * cols + c] as f32 * scales[r];
                let err = (deq - w[r * cols + c]).abs();
                assert!(
                    err <= scales[r] * 0.5 + 1e-7,
                    "row {r} col {c}: |{deq} - {}| > scale/2 = {}",
                    w[r * cols + c],
                    scales[r] * 0.5
                );
            }
        }
    }

    #[test]
    fn int8_gemm_tracks_f32_within_documented_tolerance() {
        // The DESIGN.md §11 quantization contract: ≤ 1e-2 relative error
        // (scaled by the row magnitude) against the f32 result, per
        // layout. The f32 path itself stays exact — only the quantized
        // dtype is allowed this slack.
        let mut rng = Rng::seed_from_u64(41);
        let (m, k, n) = (5, 64, 24);
        let a = randv(&mut rng, m * k, 1.0);
        for layout in [Layout::NN, Layout::NT, Layout::TN] {
            let (b_rows, b_cols, y_len) = match layout {
                Layout::NN => (k, n, m * n),
                Layout::NT => (n, k, m * n),
                Layout::TN => (m, n, k * n),
            };
            let b = randv(&mut rng, b_rows * b_cols, 0.5);
            let (q, scales) = quantize_rows_i8(&b, b_rows, b_cols);
            let qb = BData::Int8 { q: &q, scales: &scales };
            let mut y_f32 = vec![0.0f32; y_len];
            gemm(GemmSpec::new(layout, &mut y_f32, &a, b.as_slice(), m, k, n), None);
            let mut y_i8 = vec![0.0f32; y_len];
            gemm(GemmSpec::new(layout, &mut y_i8, &a, qb, m, k, n), None);
            let norm = y_f32.iter().fold(0.0f32, |mx, v| mx.max(v.abs())).max(1e-6);
            for (i, (p, qv)) in y_f32.iter().zip(&y_i8).enumerate() {
                assert!(
                    (p - qv).abs() / norm <= 1e-2,
                    "{layout:?} elem {i}: f32 {p} vs int8 {qv} (norm {norm})"
                );
            }
        }
    }

    #[test]
    fn rmsnorm_unit_scale_normalizes() {
        let x = vec![3.0, -4.0, 0.0, 0.0];
        let w = vec![1.0; 4];
        let mut out = vec![0.0; 4];
        let inv = rmsnorm(&mut out, &x, &w, 0.0);
        // rms = sqrt(25/4) = 2.5
        assert!((inv - 0.4).abs() < 1e-6);
        assert!((out[0] - 1.2).abs() < 1e-6);
        assert!((out[1] + 1.6).abs() < 1e-6);
    }

    #[test]
    fn rmsnorm_backward_matches_finite_difference() {
        let mut rng = Rng::seed_from_u64(7);
        let d = 6;
        let x = randv(&mut rng, d, 1.0);
        let w = randv(&mut rng, d, 0.5);
        let dy = randv(&mut rng, d, 1.0);
        let eps = 1e-5f32;
        let mut out = vec![0.0; d];
        let inv = rmsnorm(&mut out, &x, &w, eps);
        let mut dx = vec![0.0; d];
        rmsnorm_backward(&mut dx, &dy, &x, &w, inv);

        let h = 1e-3f32;
        for i in 0..d {
            let mut xp = x.clone();
            xp[i] += h;
            let mut xm = x.clone();
            xm[i] -= h;
            let mut op = vec![0.0; d];
            let mut om = vec![0.0; d];
            rmsnorm(&mut op, &xp, &w, eps);
            rmsnorm(&mut om, &xm, &w, eps);
            let mut num = 0.0f32;
            for j in 0..d {
                num += dy[j] * (op[j] - om[j]) / (2.0 * h);
            }
            assert!(
                (num - dx[i]).abs() < 5e-3,
                "dx[{i}]: analytic {} vs numeric {num}",
                dx[i]
            );
        }
    }

    #[test]
    fn rope_roundtrips() {
        let mut rng = Rng::seed_from_u64(3);
        let (heads, hd) = (2, 8);
        let orig = randv(&mut rng, heads * hd, 1.0);
        let mut row = orig.clone();
        rope(&mut row, heads, hd, 17, 1e4, 1.0);
        assert!(row.iter().zip(&orig).any(|(a, b)| (a - b).abs() > 1e-4));
        rope(&mut row, heads, hd, 17, 1e4, -1.0);
        for (a, b) in row.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn rope_preserves_norm() {
        let mut rng = Rng::seed_from_u64(4);
        let v = randv(&mut rng, 8, 1.0);
        let n0: f32 = v.iter().map(|x| x * x).sum();
        let mut r = v;
        rope(&mut r, 1, 8, 99, 5e5, 1.0);
        let n1: f32 = r.iter().map(|x| x * x).sum();
        assert!((n0 - n1).abs() < 1e-4);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut x = vec![1e4, 1e4 + 1.0, 1e4 - 2.0];
        softmax_inplace(&mut x);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!(x[1] > x[0] && x[0] > x[2]);
    }

    #[test]
    fn silu_grad_matches_finite_difference() {
        for &x in &[-3.0f32, -0.5, 0.0, 0.7, 4.0] {
            let h = 1e-3;
            let num = (silu(x + h) - silu(x - h)) / (2.0 * h);
            assert!((num - silu_grad(x)).abs() < 1e-3, "at {x}");
        }
    }

    fn test_bank(
        rng: &mut Rng,
        slots: usize,
        din: usize,
        r: usize,
        dout: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let a = randv(rng, slots * din * r, 0.3);
        let b = randv(rng, slots * r * dout, 0.3);
        let scaling = (0..slots).map(|i| 0.5 + i as f32 * 0.25).collect();
        (a, b, scaling)
    }

    #[test]
    fn segmentation_counting_sort_is_stable_and_complete() {
        let adapters = [2i32, -1, 0, 1, 2, -1, 3, 0, 2];
        let seg = SmlmSegmentation::compute(&adapters, 5);
        assert_eq!(seg.slots(), 5);
        assert_eq!(seg.routed_rows(), 7);
        assert_eq!(seg.rows(0), &[2, 7]); // batch order preserved
        assert_eq!(seg.rows(1), &[3]);
        assert_eq!(seg.rows(2), &[0, 4, 8]);
        assert_eq!(seg.rows(3), &[6]);
        assert_eq!(seg.rows(4), &[] as &[usize]);
        assert_eq!(seg.busy_slots(), &[0, 1, 2, 3]);
    }

    #[test]
    fn smlm_segmented_matches_per_row_mixed_batch() {
        let mut rng = Rng::seed_from_u64(11);
        let (slots, din, r, dout) = (4, 12, 3, 10);
        let (a, b, scaling) = test_bank(&mut rng, slots, din, r, dout);
        let bank = LoraBankView { a: &a, b: &b, scaling: &scaling, rank: r, din, dout };
        let n = 9;
        let x = randv(&mut rng, n * din, 1.0);
        // Mixed adapters including base-only rows and a slot used twice.
        let adapters = vec![2, -1, 0, 1, 2, -1, 3, 0, 2];
        let seg = SmlmSegmentation::compute(&adapters, slots);
        let pool = ThreadPool::new(2);
        let mut y_seg = randv(&mut rng, n * dout, 1.0); // non-zero: += semantics
        let mut y_ref = y_seg.clone();
        smlm_segmented(&pool, &x, &seg, &bank, &mut y_seg);
        smlm_per_row(&x, &adapters, &bank, &mut y_ref);
        for (i, (p, q)) in y_seg.iter().zip(&y_ref).enumerate() {
            assert!((p - q).abs() < 1e-5, "elem {i}: {p} vs {q}");
        }
        // Base-only rows untouched (row 1 spans dout..2*dout).
        let before = &y_ref[dout..2 * dout];
        assert_eq!(&y_seg[dout..2 * dout], before);
    }

    #[test]
    fn smlm_segmented_is_bitwise_thread_count_invariant() {
        let mut rng = Rng::seed_from_u64(17);
        let (slots, din, r, dout) = (4, 12, 3, 10);
        let (a, b, scaling) = test_bank(&mut rng, slots, din, r, dout);
        let bank = LoraBankView { a: &a, b: &b, scaling: &scaling, rank: r, din, dout };
        // Mixed batch AND a single-busy-segment batch (exercising the
        // hot-segment row-blocking) must both be thread-count invariant.
        for adapters in [vec![2, -1, 0, 1, 2, -1, 3, 0, 2], vec![1, 1, -1, 1, 1, 1, -1, 1, 1]] {
            let n = adapters.len();
            let x = randv(&mut rng, n * din, 1.0);
            let y0 = randv(&mut rng, n * dout, 1.0);
            let seg = SmlmSegmentation::compute(&adapters, slots);
            let mut y1 = y0.clone();
            smlm_segmented(&ThreadPool::new(1), &x, &seg, &bank, &mut y1);
            for threads in [2usize, 4, 7] {
                let mut yn = y0.clone();
                smlm_segmented(&ThreadPool::new(threads), &x, &seg, &bank, &mut yn);
                for (i, (p, q)) in y1.iter().zip(&yn).enumerate() {
                    assert_eq!(
                        p.to_bits(),
                        q.to_bits(),
                        "elem {i}: threads=1 {p} vs threads={threads} {q}"
                    );
                }
            }
        }
    }

    #[test]
    fn smlm_all_base_rows_is_noop() {
        let mut rng = Rng::seed_from_u64(13);
        let (slots, din, r, dout) = (2, 6, 2, 5);
        let (a, b, scaling) = test_bank(&mut rng, slots, din, r, dout);
        let bank = LoraBankView { a: &a, b: &b, scaling: &scaling, rank: r, din, dout };
        let x = randv(&mut rng, 3 * din, 1.0);
        let y0 = randv(&mut rng, 3 * dout, 1.0);
        let mut y = y0.clone();
        let seg = SmlmSegmentation::compute(&[-1, -1, -1], slots);
        smlm_segmented(&ThreadPool::new(2), &x, &seg, &bank, &mut y);
        assert_eq!(y, y0);
    }
}
