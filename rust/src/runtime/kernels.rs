//! Pure-Rust CPU kernel primitives for the native backend.
//!
//! Everything here is deterministic, allocation-light, and row-major f32 —
//! the lingua franca of `HostTensor`. Two design rules keep the module
//! honest as a correctness oracle:
//!
//! 1. **Fixed accumulation order.** Every reduction walks its axis in
//!    ascending index order, so the segmented SMLM path and the per-row
//!    reference path perform bit-identical floating-point work per output
//!    element and the golden tests can compare them tightly.
//! 2. **No hidden state.** Kernels take slices in, write slices out; the
//!    backend owns all buffers.
//!
//! The flagship kernel is Segmented Multi-LoRA Multiplication (SMLM, paper
//! Section 3.1): rows of a mixed-adapter batch are sorted into per-adapter
//! segments and each segment issues one gathered two-stage matmul, instead
//! of one pair of rank-r products per row. The sort lives in
//! [`SmlmSegmentation`] — a flat counting sort computed **once per batch**
//! and shared across every layer and LoRA site of a launch — and the
//! segments execute in parallel on the backend's
//! [`ThreadPool`](crate::runtime::parallel::ThreadPool). [`smlm_per_row`]
//! is the naive reference kept as the ablation baseline.

use crate::runtime::parallel::{SharedSliceMut, ThreadPool};

/// y[m×n] += a[m×k] · b[k×n] (row-major, accumulate).
pub fn gemm_nn(y: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(y.len(), m * n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    // No zero-skip branch: a per-element branch on the hot path only paid
    // off for empty LoRA bank slots, which the backend now guards one
    // level up (`NativeBackend::mask_unloaded` routes rows of all-zero /
    // zero-scaled slots to base-only before any kernel runs).
    for i in 0..m {
        let yr = &mut y[i * n..(i + 1) * n];
        for l in 0..k {
            let av = a[i * k + l];
            let br = &b[l * n..(l + 1) * n];
            for (yy, bb) in yr.iter_mut().zip(br) {
                *yy += av * bb;
            }
        }
    }
}

/// y[m×n] += a[m×k] · bᵀ, where b is stored [n×k] (accumulate).
pub fn gemm_nt(y: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(y.len(), m * n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let br = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (aa, bb) in ar.iter().zip(br) {
                acc += aa * bb;
            }
            y[i * n + j] += acc;
        }
    }
}

/// y[k×n] += aᵀ · b, where a is stored [m×k] and b is [m×n] (accumulate).
/// This is the dW shape: columns of the input against rows of the gradient.
pub fn gemm_tn(y: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(y.len(), k * n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    for i in 0..m {
        let br = &b[i * n..(i + 1) * n];
        for l in 0..k {
            let av = a[i * k + l];
            let yr = &mut y[l * n..(l + 1) * n];
            for (yy, bb) in yr.iter_mut().zip(br) {
                *yy += av * bb;
            }
        }
    }
}

/// RMSNorm: out_i = x_i · w_i / sqrt(mean(x²) + eps). Returns the inverse
/// RMS (the backward pass reuses it).
pub fn rmsnorm(out: &mut [f32], x: &[f32], w: &[f32], eps: f32) -> f32 {
    debug_assert_eq!(out.len(), x.len());
    debug_assert_eq!(w.len(), x.len());
    let mut ms = 0.0f32;
    for &v in x {
        ms += v * v;
    }
    let inv_rms = 1.0 / (ms / x.len() as f32 + eps).sqrt();
    for ((o, &xv), &wv) in out.iter_mut().zip(x).zip(w) {
        *o = xv * wv * inv_rms;
    }
    inv_rms
}

/// RMSNorm backward: given dy, the stashed input x and inv_rms, accumulate
/// dx. (Weight gradients are never needed — base weights are frozen.)
pub fn rmsnorm_backward(dx: &mut [f32], dy: &[f32], x: &[f32], w: &[f32], inv_rms: f32) {
    let d = x.len() as f32;
    let mut dot = 0.0f32;
    for i in 0..x.len() {
        dot += dy[i] * w[i] * x[i];
    }
    let c = dot * inv_rms * inv_rms * inv_rms / d;
    for i in 0..x.len() {
        dx[i] += dy[i] * w[i] * inv_rms - x[i] * c;
    }
}

/// Rotary position embedding over one row of `heads × head_dim`, half-dim
/// (Llama-style) rotation at absolute position `pos`. `dir` = 1.0 applies
/// RoPE; `dir` = -1.0 inverts it (the backward pass: rotation is
/// orthonormal, so the inverse is the transpose = negated angle).
///
/// One transcendental `powf` per call (the per-dim frequencies form a
/// geometric series, accumulated in f64): this sits on the per-token
/// per-layer hot path.
pub fn rope(row: &mut [f32], heads: usize, head_dim: usize, pos: usize, theta: f64, dir: f64) {
    debug_assert_eq!(row.len(), heads * head_dim);
    let half = head_dim / 2;
    let step = theta.powf(-2.0 / head_dim as f64);
    let mut freq = 1.0f64;
    for i in 0..half {
        let ang = dir * pos as f64 * freq;
        let (sin, cos) = (ang.sin() as f32, ang.cos() as f32);
        for h in 0..heads {
            let base = h * head_dim;
            let (a, b) = (row[base + i], row[base + half + i]);
            row[base + i] = a * cos - b * sin;
            row[base + half + i] = a * sin + b * cos;
        }
        freq *= step;
    }
}

/// Numerically stable in-place softmax over `x`.
pub fn softmax_inplace(x: &mut [f32]) {
    let mut mx = f32::NEG_INFINITY;
    for &v in x.iter() {
        if v > mx {
            mx = v;
        }
    }
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// SiLU: x · sigmoid(x).
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// d/dx SiLU(x) = sigmoid(x) · (1 + x · (1 − sigmoid(x))).
pub fn silu_grad(x: f32) -> f32 {
    let s = 1.0 / (1.0 + (-x).exp());
    s * (1.0 + x * (1.0 - s))
}

/// A borrowed view over one LoRA site's stacked bank.
#[derive(Debug, Clone, Copy)]
pub struct LoraBankView<'a> {
    /// `[slots, din, r]` — the A factors, one block per bank slot.
    pub a: &'a [f32],
    /// `[slots, r, dout]` — the B factors.
    pub b: &'a [f32],
    /// `[slots]` — per-slot scaling (alpha/r, or the dynamic override).
    pub scaling: &'a [f32],
    pub rank: usize,
    pub din: usize,
    pub dout: usize,
}

impl<'a> LoraBankView<'a> {
    pub fn slots(&self) -> usize {
        self.scaling.len()
    }

    fn a_slot(&self, s: usize) -> &'a [f32] {
        let n = self.din * self.rank;
        &self.a[s * n..(s + 1) * n]
    }

    fn b_slot(&self, s: usize) -> &'a [f32] {
        let n = self.rank * self.dout;
        &self.b[s * n..(s + 1) * n]
    }
}

/// The per-batch row sort behind [`smlm_segmented`]: a flat, stable
/// counting sort of adapter-routed rows into per-slot segments.
///
/// Computed **once per launch** from the batch's per-row adapter ids and
/// shared across every layer and LoRA site (the segments depend only on
/// the routing, never on the weights) — hoisting what used to be a
/// `Vec<Vec<usize>>` rebuild inside every kernel call.
#[derive(Debug, Clone)]
pub struct SmlmSegmentation {
    /// Adapter-routed row indices, grouped by slot; batch order inside a
    /// group (stability fixes the accumulation order).
    order: Vec<usize>,
    /// `[slots + 1]` prefix offsets into `order`.
    start: Vec<usize>,
    /// Slots with at least one routed row (precomputed here so the
    /// per-site kernel calls allocate nothing).
    busy: Vec<usize>,
}

impl SmlmSegmentation {
    /// Counting-sort `adapters` (one id per row, `-1` = base-only) into
    /// per-slot segments.
    pub fn compute(adapters: &[i32], slots: usize) -> Self {
        let mut start = vec![0usize; slots + 1];
        for &a in adapters {
            if a >= 0 {
                debug_assert!((a as usize) < slots, "adapter {a} out of bank range");
                start[a as usize + 1] += 1;
            }
        }
        for s in 0..slots {
            start[s + 1] += start[s];
        }
        let mut cursor = start[..slots].to_vec();
        let mut order = vec![0usize; start[slots]];
        for (i, &a) in adapters.iter().enumerate() {
            if a >= 0 {
                order[cursor[a as usize]] = i;
                cursor[a as usize] += 1;
            }
        }
        let busy = (0..slots).filter(|&s| start[s + 1] > start[s]).collect();
        Self { order, start, busy }
    }

    pub fn slots(&self) -> usize {
        self.start.len() - 1
    }

    /// Row indices routed to slot `s`, in batch order.
    pub fn rows(&self, s: usize) -> &[usize] {
        &self.order[self.start[s]..self.start[s + 1]]
    }

    /// Total adapter-routed rows (base-only rows excluded).
    pub fn routed_rows(&self) -> usize {
        self.order.len()
    }

    /// Slots with at least one routed row (precomputed, allocation-free).
    pub fn busy_slots(&self) -> &[usize] {
        &self.busy
    }
}

/// One work unit's gathered two-stage product over `rows` (a segment or a
/// row block of one): gather → `x·A_s` → `·B_s` → scatter-accumulate with
/// the slot scaling. `xs`/`mid`/`ys` are caller-provided scratch (reused
/// across the units on one lane). Each output row's math involves only
/// that row, so how rows are blocked never changes a bit of output.
///
/// # Safety
///
/// `y.slice` is touched only at `rows`; the caller must guarantee no
/// other concurrent user writes those rows.
unsafe fn smlm_unit(
    x: &[f32],
    rows: &[usize],
    s: usize,
    bank: &LoraBankView,
    y: &SharedSliceMut<f32>,
    xs: &mut Vec<f32>,
    mid: &mut Vec<f32>,
    ys: &mut Vec<f32>,
) {
    let (din, dout, r) = (bank.din, bank.dout, bank.rank);
    let m = rows.len();
    xs.clear();
    xs.reserve(m * din);
    for &i in rows {
        xs.extend_from_slice(&x[i * din..(i + 1) * din]);
    }
    mid.clear();
    mid.resize(m * r, 0.0);
    gemm_nn(mid, xs, bank.a_slot(s), m, din, r);
    ys.clear();
    ys.resize(m * dout, 0.0);
    gemm_nn(ys, mid, bank.b_slot(s), m, r, dout);
    let scale = bank.scaling[s];
    for (seg_i, &i) in rows.iter().enumerate() {
        let src = &ys[seg_i * dout..(seg_i + 1) * dout];
        let dst = y.slice(i * dout, dout);
        for (d, v) in dst.iter_mut().zip(src) {
            *d += scale * v;
        }
    }
}

/// Segmented Multi-LoRA Multiplication: `y[i] += scale_s · (x[i]·A_s)·B_s`
/// for each row `i` routed to slot `s` by `seg`; base-only rows are
/// untouched.
///
/// Each segment gathers its rows once and issues ONE two-stage matmul, so
/// the number of rank-r products scales with the number of *distinct
/// adapters in the batch*, not with the batch size — the paper's answer to
/// the per-row adapter loop that S-LoRA's bgmv kernels also attack.
///
/// Parallelism is partition-only and therefore bitwise thread-count
/// invariant: busy segments are cut into row-block work units no larger
/// than `ceil(routed_rows / threads)` (so one hot adapter cannot pin a
/// single lane), and lanes take contiguous row-weighted runs of units.
/// Unit boundaries depend on the lane count, but every output row's math
/// involves only that row, so blocking never changes a bit of output.
pub fn smlm_segmented(
    pool: &ThreadPool,
    x: &[f32],
    seg: &SmlmSegmentation,
    bank: &LoraBankView,
    y: &mut [f32],
) {
    let (din, dout) = (bank.din, bank.dout);
    debug_assert_eq!(seg.slots(), bank.slots());
    debug_assert_eq!(x.len() * dout, y.len() * din);
    let busy = seg.busy_slots();
    if busy.is_empty() {
        return;
    }
    // Remaining per-call allocations are bounded by the number of busy
    // segments and lanes (work-unit list, gather/product scratch), never
    // by rows.
    let total = seg.routed_rows();
    let block = total.div_ceil(pool.threads());
    // (slot, row range within the segment) work units.
    let mut units: Vec<(usize, usize, usize)> = Vec::new();
    for &s in busy {
        let m = seg.rows(s).len();
        let mut r0 = 0;
        while r0 < m {
            let r1 = (r0 + block).min(m);
            units.push((s, r0, r1));
            r0 = r1;
        }
    }
    // Row-weighted contiguous cuts over the units (prefix-sum partition
    // points) keep lane loads balanced even when unit sizes are ragged.
    let mut prefix = Vec::with_capacity(units.len() + 1);
    prefix.push(0usize);
    for &(_, r0, r1) in &units {
        prefix.push(prefix.last().unwrap() + (r1 - r0));
    }

    let shared = SharedSliceMut::new(y);
    pool.par_partition_weighted(&prefix, |range| {
        // Per-lane scratch, reused across this lane's units.
        let (mut xs, mut mid, mut ys) = (Vec::new(), Vec::new(), Vec::new());
        for &(s, r0, r1) in &units[range] {
            let rows = &seg.rows(s)[r0..r1];
            // SAFETY: units own disjoint row sets and each unit is
            // processed by exactly one lane, so concurrent lanes never
            // write overlapping `y` rows.
            unsafe {
                smlm_unit(x, rows, s, bank, &shared, &mut xs, &mut mid, &mut ys);
            }
        }
    });
}

/// Per-row reference for [`smlm_segmented`]: one pair of rank-r products
/// per row. Kept as the correctness oracle and the ablation baseline the
/// kernel bench sweeps against.
pub fn smlm_per_row(x: &[f32], adapters: &[i32], bank: &LoraBankView, y: &mut [f32]) {
    let (din, dout, r) = (bank.din, bank.dout, bank.rank);
    debug_assert_eq!(x.len(), adapters.len() * din);
    debug_assert_eq!(y.len(), adapters.len() * dout);
    let mut mid = vec![0.0f32; r];
    let mut row = vec![0.0f32; dout];
    for (i, &a) in adapters.iter().enumerate() {
        if a < 0 {
            continue;
        }
        let s = a as usize;
        let xr = &x[i * din..(i + 1) * din];
        mid.iter_mut().for_each(|v| *v = 0.0);
        gemm_nn(&mut mid, xr, bank.a_slot(s), 1, din, r);
        row.iter_mut().for_each(|v| *v = 0.0);
        gemm_nn(&mut row, &mid, bank.b_slot(s), 1, r, dout);
        let scale = bank.scaling[s];
        let dst = &mut y[i * dout..(i + 1) * dout];
        for (d, v) in dst.iter_mut().zip(&row) {
            *d += scale * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * scale).collect()
    }

    #[test]
    fn gemm_nn_matches_manual() {
        // [2x3] · [3x2]
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        let mut y = vec![0.0; 4];
        gemm_nn(&mut y, &a, &b, 2, 3, 2);
        assert_eq!(y, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn gemm_transposes_agree() {
        let mut rng = Rng::seed_from_u64(1);
        let (m, k, n) = (3, 5, 4);
        let a = randv(&mut rng, m * k, 1.0);
        let b = randv(&mut rng, k * n, 1.0);
        let mut y = vec![0.0; m * n];
        gemm_nn(&mut y, &a, &b, m, k, n);

        // nt: store b transposed [n×k], must reproduce y.
        let mut bt = vec![0.0; n * k];
        for i in 0..k {
            for j in 0..n {
                bt[j * k + i] = b[i * n + j];
            }
        }
        let mut y2 = vec![0.0; m * n];
        gemm_nt(&mut y2, &a, &bt, m, k, n);
        for (p, q) in y.iter().zip(&y2) {
            assert!((p - q).abs() < 1e-5);
        }

        // tn: store a transposed [k×m] as the "a" operand with m/k swapped.
        let mut at = vec![0.0; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let mut y3 = vec![0.0; m * n];
        gemm_tn(&mut y3, &at, &b, k, m, n);
        for (p, q) in y.iter().zip(&y3) {
            assert!((p - q).abs() < 1e-5);
        }
    }

    #[test]
    fn rmsnorm_unit_scale_normalizes() {
        let x = vec![3.0, -4.0, 0.0, 0.0];
        let w = vec![1.0; 4];
        let mut out = vec![0.0; 4];
        let inv = rmsnorm(&mut out, &x, &w, 0.0);
        // rms = sqrt(25/4) = 2.5
        assert!((inv - 0.4).abs() < 1e-6);
        assert!((out[0] - 1.2).abs() < 1e-6);
        assert!((out[1] + 1.6).abs() < 1e-6);
    }

    #[test]
    fn rmsnorm_backward_matches_finite_difference() {
        let mut rng = Rng::seed_from_u64(7);
        let d = 6;
        let x = randv(&mut rng, d, 1.0);
        let w = randv(&mut rng, d, 0.5);
        let dy = randv(&mut rng, d, 1.0);
        let eps = 1e-5f32;
        let mut out = vec![0.0; d];
        let inv = rmsnorm(&mut out, &x, &w, eps);
        let mut dx = vec![0.0; d];
        rmsnorm_backward(&mut dx, &dy, &x, &w, inv);

        let h = 1e-3f32;
        for i in 0..d {
            let mut xp = x.clone();
            xp[i] += h;
            let mut xm = x.clone();
            xm[i] -= h;
            let mut op = vec![0.0; d];
            let mut om = vec![0.0; d];
            rmsnorm(&mut op, &xp, &w, eps);
            rmsnorm(&mut om, &xm, &w, eps);
            let mut num = 0.0f32;
            for j in 0..d {
                num += dy[j] * (op[j] - om[j]) / (2.0 * h);
            }
            assert!(
                (num - dx[i]).abs() < 5e-3,
                "dx[{i}]: analytic {} vs numeric {num}",
                dx[i]
            );
        }
    }

    #[test]
    fn rope_roundtrips() {
        let mut rng = Rng::seed_from_u64(3);
        let (heads, hd) = (2, 8);
        let orig = randv(&mut rng, heads * hd, 1.0);
        let mut row = orig.clone();
        rope(&mut row, heads, hd, 17, 1e4, 1.0);
        assert!(row.iter().zip(&orig).any(|(a, b)| (a - b).abs() > 1e-4));
        rope(&mut row, heads, hd, 17, 1e4, -1.0);
        for (a, b) in row.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn rope_preserves_norm() {
        let mut rng = Rng::seed_from_u64(4);
        let v = randv(&mut rng, 8, 1.0);
        let n0: f32 = v.iter().map(|x| x * x).sum();
        let mut r = v;
        rope(&mut r, 1, 8, 99, 5e5, 1.0);
        let n1: f32 = r.iter().map(|x| x * x).sum();
        assert!((n0 - n1).abs() < 1e-4);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut x = vec![1e4, 1e4 + 1.0, 1e4 - 2.0];
        softmax_inplace(&mut x);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!(x[1] > x[0] && x[0] > x[2]);
    }

    #[test]
    fn silu_grad_matches_finite_difference() {
        for &x in &[-3.0f32, -0.5, 0.0, 0.7, 4.0] {
            let h = 1e-3;
            let num = (silu(x + h) - silu(x - h)) / (2.0 * h);
            assert!((num - silu_grad(x)).abs() < 1e-3, "at {x}");
        }
    }

    fn test_bank(
        rng: &mut Rng,
        slots: usize,
        din: usize,
        r: usize,
        dout: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let a = randv(rng, slots * din * r, 0.3);
        let b = randv(rng, slots * r * dout, 0.3);
        let scaling = (0..slots).map(|i| 0.5 + i as f32 * 0.25).collect();
        (a, b, scaling)
    }

    #[test]
    fn segmentation_counting_sort_is_stable_and_complete() {
        let adapters = [2i32, -1, 0, 1, 2, -1, 3, 0, 2];
        let seg = SmlmSegmentation::compute(&adapters, 5);
        assert_eq!(seg.slots(), 5);
        assert_eq!(seg.routed_rows(), 7);
        assert_eq!(seg.rows(0), &[2, 7]); // batch order preserved
        assert_eq!(seg.rows(1), &[3]);
        assert_eq!(seg.rows(2), &[0, 4, 8]);
        assert_eq!(seg.rows(3), &[6]);
        assert_eq!(seg.rows(4), &[] as &[usize]);
        assert_eq!(seg.busy_slots(), &[0, 1, 2, 3]);
    }

    #[test]
    fn smlm_segmented_matches_per_row_mixed_batch() {
        let mut rng = Rng::seed_from_u64(11);
        let (slots, din, r, dout) = (4, 12, 3, 10);
        let (a, b, scaling) = test_bank(&mut rng, slots, din, r, dout);
        let bank = LoraBankView { a: &a, b: &b, scaling: &scaling, rank: r, din, dout };
        let n = 9;
        let x = randv(&mut rng, n * din, 1.0);
        // Mixed adapters including base-only rows and a slot used twice.
        let adapters = vec![2, -1, 0, 1, 2, -1, 3, 0, 2];
        let seg = SmlmSegmentation::compute(&adapters, slots);
        let pool = ThreadPool::new(2);
        let mut y_seg = randv(&mut rng, n * dout, 1.0); // non-zero: += semantics
        let mut y_ref = y_seg.clone();
        smlm_segmented(&pool, &x, &seg, &bank, &mut y_seg);
        smlm_per_row(&x, &adapters, &bank, &mut y_ref);
        for (i, (p, q)) in y_seg.iter().zip(&y_ref).enumerate() {
            assert!((p - q).abs() < 1e-5, "elem {i}: {p} vs {q}");
        }
        // Base-only rows untouched (row 1 spans dout..2*dout).
        let before = &y_ref[dout..2 * dout];
        assert_eq!(&y_seg[dout..2 * dout], before);
    }

    #[test]
    fn smlm_segmented_is_bitwise_thread_count_invariant() {
        let mut rng = Rng::seed_from_u64(17);
        let (slots, din, r, dout) = (4, 12, 3, 10);
        let (a, b, scaling) = test_bank(&mut rng, slots, din, r, dout);
        let bank = LoraBankView { a: &a, b: &b, scaling: &scaling, rank: r, din, dout };
        // Mixed batch AND a single-busy-segment batch (exercising the
        // hot-segment row-blocking) must both be thread-count invariant.
        for adapters in [vec![2, -1, 0, 1, 2, -1, 3, 0, 2], vec![1, 1, -1, 1, 1, 1, -1, 1, 1]] {
            let n = adapters.len();
            let x = randv(&mut rng, n * din, 1.0);
            let y0 = randv(&mut rng, n * dout, 1.0);
            let seg = SmlmSegmentation::compute(&adapters, slots);
            let mut y1 = y0.clone();
            smlm_segmented(&ThreadPool::new(1), &x, &seg, &bank, &mut y1);
            for threads in [2usize, 4, 7] {
                let mut yn = y0.clone();
                smlm_segmented(&ThreadPool::new(threads), &x, &seg, &bank, &mut yn);
                for (i, (p, q)) in y1.iter().zip(&yn).enumerate() {
                    assert_eq!(
                        p.to_bits(),
                        q.to_bits(),
                        "elem {i}: threads=1 {p} vs threads={threads} {q}"
                    );
                }
            }
        }
    }

    #[test]
    fn smlm_all_base_rows_is_noop() {
        let mut rng = Rng::seed_from_u64(13);
        let (slots, din, r, dout) = (2, 6, 2, 5);
        let (a, b, scaling) = test_bank(&mut rng, slots, din, r, dout);
        let bank = LoraBankView { a: &a, b: &b, scaling: &scaling, rank: r, din, dout };
        let x = randv(&mut rng, 3 * din, 1.0);
        let y0 = randv(&mut rng, 3 * dout, 1.0);
        let mut y = y0.clone();
        let seg = SmlmSegmentation::compute(&[-1, -1, -1], slots);
        smlm_segmented(&ThreadPool::new(2), &x, &seg, &bank, &mut y);
        assert_eq!(y, y0);
    }
}
