//! Typed view of `artifacts/manifest.json` — the AOT contract with L2.
//! Parsed with the in-tree JSON codec (`util::json`); entry order is
//! preserved (it is the compile order).

use std::fs;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::DType;
use crate::util::json::{self, Json};

/// Shape+dtype of one entry argument or result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(v: &Json) -> Result<Self> {
        let dtype = match v.req("dtype")?.as_str()? {
            "f32" => DType::F32,
            "i32" => DType::I32,
            other => bail!("unknown dtype {other}"),
        };
        Ok(Self {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v.req("shape")?.usize_vec()?,
            dtype,
        })
    }
}

/// One AOT-compiled entry point.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl EntrySpec {
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|s| s.name == name)
    }
}

/// Index record of one tensor inside `weights.bin`.
#[derive(Debug, Clone)]
pub struct WeightRecord {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// Model geometry mirrored from `python/compile/configs.py`.
#[derive(Debug, Clone)]
pub struct ModelGeometry {
    pub vocab_size: usize,
    pub hidden_size: usize,
    pub intermediate_size: usize,
    pub num_layers: usize,
    pub num_heads: usize,
    pub num_kv_heads: usize,
    pub head_dim: usize,
    pub rope_theta: f64,
    pub rms_eps: f64,
    pub max_cache_len: usize,
    pub q_dim: usize,
    pub kv_dim: usize,
}

impl ModelGeometry {
    /// (din, dout) of a LoRA-targetable projection, by manifest target name.
    pub fn lora_target_dims(&self, module: &str) -> Option<(usize, usize)> {
        match module {
            "q" => Some((self.hidden_size, self.q_dim)),
            "k" | "v" => Some((self.hidden_size, self.kv_dim)),
            "o" => Some((self.q_dim, self.hidden_size)),
            "gate" | "up" => Some((self.hidden_size, self.intermediate_size)),
            "down" => Some((self.intermediate_size, self.hidden_size)),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct LoraGeometry {
    pub max_adapters: usize,
    pub rank: usize,
    pub alpha: f64,
    pub dropout: f64,
    pub targets: Vec<String>,
    pub scaling: f64,
}

#[derive(Debug, Clone)]
pub struct UnifiedShape {
    pub ft_batch: usize,
    pub ft_seq: usize,
    pub pf_batch: usize,
    pub pf_seq: usize,
    pub dec_batch: usize,
}

impl UnifiedShape {
    pub fn total_tokens(&self) -> usize {
        self.ft_batch * self.ft_seq + self.pf_batch * self.pf_seq + self.dec_batch
    }
}

#[derive(Debug, Clone)]
pub struct BucketTable {
    /// (batch, seq) prefill buckets.
    pub prefill: Vec<(usize, usize)>,
    /// Decode batch buckets.
    pub decode: Vec<usize>,
    /// (batch, seq) training buckets.
    pub train: Vec<(usize, usize)>,
    pub unified: Vec<UnifiedShape>,
}

impl BucketTable {
    /// Smallest prefill bucket covering (batch, seq), if any.
    pub fn prefill_bucket(&self, batch: usize, seq: usize) -> Option<(usize, usize)> {
        self.prefill
            .iter()
            .copied()
            .filter(|&(b, s)| b >= batch && s >= seq)
            .min_by_key(|&(b, s)| b * s)
    }

    /// Smallest decode bucket with capacity for `batch` rows.
    pub fn decode_bucket(&self, batch: usize) -> Option<usize> {
        self.decode.iter().copied().filter(|&b| b >= batch).min()
    }

    pub fn max_decode(&self) -> usize {
        self.decode.iter().copied().max().unwrap_or(0)
    }

    pub fn train_bucket(&self, batch: usize, seq: usize) -> Option<(usize, usize)> {
        self.train
            .iter()
            .copied()
            .filter(|&(b, s)| b >= batch && s >= seq)
            .min_by_key(|&(b, s)| b * s)
    }
}

#[derive(Debug, Clone)]
pub struct BuildInfo {
    pub model: ModelGeometry,
    pub lora: LoraGeometry,
    pub buckets: BucketTable,
    pub seed: u64,
    pub sgmv_tile_rows: usize,
}

/// The whole manifest. `entries` preserves file order (= compile order).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub format_version: u64,
    pub build: BuildInfo,
    pub entries: Vec<(String, EntrySpec)>,
    pub weights: Vec<WeightRecord>,
    pub weights_file: String,
}

fn pair_list(v: &Json) -> Result<Vec<(usize, usize)>> {
    v.as_arr()?
        .iter()
        .map(|p| {
            let xs = p.usize_vec()?;
            if xs.len() != 2 {
                bail!("expected [batch, seq] pair");
            }
            Ok((xs[0], xs[1]))
        })
        .collect()
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        let v = json::parse(&text).context("parsing manifest.json")?;

        let build = v.req("build")?;
        let m = build.req("model")?;
        let model = ModelGeometry {
            vocab_size: m.req("vocab_size")?.as_usize()?,
            hidden_size: m.req("hidden_size")?.as_usize()?,
            intermediate_size: m.req("intermediate_size")?.as_usize()?,
            num_layers: m.req("num_layers")?.as_usize()?,
            num_heads: m.req("num_heads")?.as_usize()?,
            num_kv_heads: m.req("num_kv_heads")?.as_usize()?,
            head_dim: m.req("head_dim")?.as_usize()?,
            rope_theta: m.req("rope_theta")?.as_f64()?,
            rms_eps: m.req("rms_eps")?.as_f64()?,
            max_cache_len: m.req("max_cache_len")?.as_usize()?,
            q_dim: m.req("q_dim")?.as_usize()?,
            kv_dim: m.req("kv_dim")?.as_usize()?,
        };
        let l = build.req("lora")?;
        let lora = LoraGeometry {
            max_adapters: l.req("max_adapters")?.as_usize()?,
            rank: l.req("rank")?.as_usize()?,
            alpha: l.req("alpha")?.as_f64()?,
            dropout: l.req("dropout")?.as_f64()?,
            targets: l
                .req("targets")?
                .as_arr()?
                .iter()
                .map(|t| Ok(t.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
            scaling: l.req("scaling")?.as_f64()?,
        };
        let b = build.req("buckets")?;
        let unified = b
            .req("unified")?
            .as_arr()?
            .iter()
            .map(|u| {
                Ok(UnifiedShape {
                    ft_batch: u.req("ft_batch")?.as_usize()?,
                    ft_seq: u.req("ft_seq")?.as_usize()?,
                    pf_batch: u.req("pf_batch")?.as_usize()?,
                    pf_seq: u.req("pf_seq")?.as_usize()?,
                    dec_batch: u.req("dec_batch")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let buckets = BucketTable {
            prefill: pair_list(b.req("prefill")?)?,
            decode: b.req("decode")?.usize_vec()?,
            train: pair_list(b.req("train")?)?,
            unified,
        };
        let build_info = BuildInfo {
            model,
            lora,
            buckets,
            seed: build.req("seed")?.as_u64()?,
            sgmv_tile_rows: build.req("sgmv_tile_rows")?.as_usize()?,
        };

        let mut entries = Vec::new();
        for (name, e) in v.req("entries")?.as_obj()? {
            let inputs = e
                .req("inputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .req("outputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            entries.push((
                name.clone(),
                EntrySpec { file: e.req("file")?.as_str()?.to_string(), inputs, outputs },
            ));
        }

        let weights = v
            .req("weights")?
            .as_arr()?
            .iter()
            .map(|w| {
                Ok(WeightRecord {
                    name: w.req("name")?.as_str()?.to_string(),
                    offset: w.req("offset")?.as_usize()?,
                    shape: w.req("shape")?.usize_vec()?,
                    dtype: w.req("dtype")?.as_str()?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            format_version: v.req("format_version")?.as_u64()?,
            build: build_info,
            entries,
            weights,
            weights_file: v.req("weights_file")?.as_str()?.to_string(),
        })
    }

    pub fn entry(&self, name: &str) -> Option<&EntrySpec> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, e)| e)
    }

    pub fn entry_names(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|(n, _)| n.as_str())
    }

    pub fn weight(&self, name: &str) -> Option<&WeightRecord> {
        self.weights.iter().find(|w| w.name == name)
    }

    /// Names of the flat base-parameter inputs, in AOT argument order.
    pub fn base_param_names(&self) -> Vec<String> {
        let mut out = vec!["base.embed".to_string()];
        for li in 0..self.build.model.num_layers {
            for w in ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown", "ln1", "ln2"] {
                out.push(format!("base.layers.{li}.{w}"));
            }
        }
        out.push("base.final_norm".to_string());
        out.push("base.lm_head".to_string());
        out
    }

    /// Names of the flat LoRA-bank inputs, in AOT argument order.
    pub fn lora_param_names(&self) -> Vec<String> {
        let mut out = Vec::new();
        for li in 0..self.build.model.num_layers {
            for m in &self.build.lora.targets {
                out.push(format!("lora.layers.{li}.{m}.a"));
                out.push(format!("lora.layers.{li}.{m}.b"));
            }
        }
        out.push("lora.scaling".to_string());
        out
    }

    /// Names of the gradient/optimizer-state arrays (a/b subset, no scaling).
    pub fn grad_param_names(&self) -> Vec<String> {
        self.lora_param_names()
            .into_iter()
            .filter(|n| !n.ends_with("scaling"))
            .collect()
    }
}

/// Missing-key errors should carry the manifest path context upward.
pub fn manifest_error(path: &Path, e: anyhow::Error) -> anyhow::Error {
    anyhow!("{}: {e}", path.display())
}
