//! L3 runtime — load AOT artifacts and execute them on the PJRT CPU client.
//!
//! The interchange contract with `python/compile/aot.py`:
//!
//! * `artifacts/manifest.json` describes every entry point (argument order,
//!   shapes, dtypes) plus model geometry and the weights index.
//! * `artifacts/<entry>.hlo.txt` is HLO **text** (not a serialized proto —
//!   xla_extension 0.5.1 rejects jax≥0.5's 64-bit instruction ids; the text
//!   parser reassigns them).
//! * `artifacts/weights.bin` holds base weights, the empty LoRA bank, the
//!   four pretrained-adapter stand-ins, and the preloaded `bank.*` copies.
//!
//! Hot-path design: weights are uploaded to the device **once** as
//! `PjRtBuffer`s and passed by reference to `execute_b`; per-step tensors
//! (tokens, lens, caches) are the only host→device traffic. Optimizer
//! outputs can be kept on device and re-pinned as the next step's inputs —
//! parameter updates never round-trip through the host.

pub mod kernels;
mod manifest;
pub mod parallel;
mod tensor;

pub use manifest::{
    BucketTable, BuildInfo, EntrySpec, LoraGeometry, Manifest, ModelGeometry, TensorSpec,
    UnifiedShape, WeightRecord,
};
pub use tensor::{DType, HostTensor};

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::bench::Stopwatch;

/// A compiled entry point plus its manifest spec.
pub struct Entry {
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
}

/// Timing of one `Runtime::execute` call, used for calibration and §Perf.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecTiming {
    /// Host→device marshalling of the per-call inputs (µs).
    pub upload_us: u64,
    /// Device execution as observed from the host (µs).
    pub execute_us: u64,
    /// Device→host copy of the requested outputs (µs).
    pub download_us: u64,
}

impl ExecTiming {
    pub fn total_us(&self) -> u64 {
        self.upload_us + self.execute_us + self.download_us
    }
}

/// One argument to [`Runtime::execute`].
pub enum Arg<'a> {
    /// Reference a device buffer previously stored with `pin`/`pin_buffer`.
    Pinned(&'a str),
    /// Upload this host tensor for the call.
    Host(&'a HostTensor),
}

enum ArgSlot {
    Pinned(String),
    Uploaded(usize),
}

/// Outputs of one execution: host tensors plus any kept-on-device buffers.
pub struct ExecOutputs {
    pub host: BTreeMap<String, HostTensor>,
    pub device: BTreeMap<String, xla::PjRtBuffer>,
}

impl ExecOutputs {
    pub fn take(&mut self, name: &str) -> Result<HostTensor> {
        self.host.remove(name).ok_or_else(|| {
            anyhow!("output {name} missing (host outputs: {:?})", self.host.keys().collect::<Vec<_>>())
        })
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.host.get(name).ok_or_else(|| anyhow!("output {name} missing"))
    }

    pub fn take_device(&mut self, name: &str) -> Result<xla::PjRtBuffer> {
        self.device
            .remove(name)
            .ok_or_else(|| anyhow!("device output {name} missing"))
    }
}

/// The PJRT runtime: one compiled executable per manifest entry.
pub struct Runtime {
    pub manifest: Manifest,
    pub artifacts_dir: PathBuf,
    client: xla::PjRtClient,
    entries: BTreeMap<String, Entry>,
    /// Device-resident persistent inputs, keyed by weight name. Uploaded
    /// once (or when an adapter is hot-swapped) and reused every call.
    resident: BTreeMap<String, xla::PjRtBuffer>,
    /// Cumulative entry compile time — reported by the Table-2 loading bench.
    pub compile_seconds: f64,
}

impl Runtime {
    /// Load the manifest and compile the entries passing `entry_filter`.
    ///
    /// Lazy/per-role loading keeps Table-2 "time to load" honest: an
    /// inference-only deployment never compiles the training entries.
    pub fn load_filtered(
        artifacts_dir: impl AsRef<Path>,
        entry_filter: impl Fn(&str) -> bool,
    ) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .context("loading manifest.json (run `make artifacts` first)")?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;

        let mut rt = Self {
            manifest,
            artifacts_dir: dir,
            client,
            entries: BTreeMap::new(),
            resident: BTreeMap::new(),
            compile_seconds: 0.0,
        };
        let names: Vec<String> = rt.manifest.entry_names().map(String::from).collect();
        for name in names {
            if entry_filter(&name) {
                rt.compile_entry(&name)?;
            }
        }
        Ok(rt)
    }

    /// Load and compile every entry.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        Self::load_filtered(artifacts_dir, |_| true)
    }

    /// Compile one entry (idempotent). Returns the compile time in seconds.
    pub fn compile_entry(&mut self, name: &str) -> Result<f64> {
        if self.entries.contains_key(name) {
            return Ok(0.0);
        }
        let spec = self
            .manifest
            .entry(name)
            .ok_or_else(|| anyhow!("manifest has no entry {name}"))?
            .clone();
        let t0 = Stopwatch::start();
        let path = self.artifacts_dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let dt = t0.elapsed_s();
        self.compile_seconds += dt;
        self.entries.insert(name.to_string(), Entry { spec, exe });
        Ok(dt)
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries.get(name).ok_or_else(|| {
            anyhow!("entry {name} not loaded (compiled: {:?})", self.entries.keys().collect::<Vec<_>>())
        })
    }

    pub fn has_entry(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    pub fn entry_names(&self) -> impl Iterator<Item = &String> {
        self.entries.keys()
    }

    /// Upload a tensor to the device and pin it under `key` for reuse.
    pub fn pin(&mut self, key: &str, tensor: &HostTensor) -> Result<()> {
        let buf = tensor.to_buffer(&self.client)?;
        self.resident.insert(key.to_string(), buf);
        Ok(())
    }

    /// Replace a pinned buffer with an already-device-resident one (e.g. an
    /// optimizer-step output) — the zero-copy parameter-update path.
    pub fn pin_buffer(&mut self, key: &str, buf: xla::PjRtBuffer) {
        self.resident.insert(key.to_string(), buf);
    }

    pub fn is_pinned(&self, key: &str) -> bool {
        self.resident.contains_key(key)
    }

    pub fn unpin(&mut self, key: &str) {
        self.resident.remove(key);
    }

    /// Download a pinned buffer back to the host (adapter save path).
    pub fn pinned_to_host(&self, key: &str, spec: &TensorSpec) -> Result<HostTensor> {
        let buf = self
            .resident
            .get(key)
            .ok_or_else(|| anyhow!("pinned buffer {key} missing"))?;
        let lit = buf.to_literal_sync().map_err(|e| anyhow!("download {key}: {e:?}"))?;
        HostTensor::from_literal(&lit, spec)
    }

    /// Execute an entry. Arguments are pinned device buffers or host tensors
    /// uploaded for this call. Outputs come back as host tensors unless
    /// listed in `keep_on_device` (those stay as buffers, for chaining).
    pub fn execute(
        &mut self,
        entry_name: &str,
        args: &[Arg<'_>],
        keep_on_device: &[&str],
    ) -> Result<(ExecOutputs, ExecTiming)> {
        let mut timing = ExecTiming::default();
        let entry = self
            .entries
            .get(entry_name)
            .ok_or_else(|| anyhow!("entry {entry_name} not loaded"))?;
        if args.len() != entry.spec.inputs.len() {
            return Err(anyhow!(
                "{entry_name}: got {} args, manifest wants {}",
                args.len(),
                entry.spec.inputs.len()
            ));
        }

        // Marshal: upload host tensors, reference pinned buffers.
        let t0 = Stopwatch::start();
        let mut uploaded: Vec<xla::PjRtBuffer> = Vec::new();
        let mut order: Vec<ArgSlot> = Vec::with_capacity(args.len());
        for (i, a) in args.iter().enumerate() {
            match a {
                Arg::Pinned(key) => {
                    if !self.resident.contains_key(*key) {
                        return Err(anyhow!("{entry_name} arg {i}: pinned buffer {key} missing"));
                    }
                    order.push(ArgSlot::Pinned((*key).to_string()));
                }
                Arg::Host(t) => {
                    let spec = &entry.spec.inputs[i];
                    if t.shape != spec.shape || t.dtype != spec.dtype {
                        return Err(anyhow!(
                            "{entry_name} arg {i} ({}): got {:?} {:?}, want {:?} {:?}",
                            spec.name, t.shape, t.dtype, spec.shape, spec.dtype
                        ));
                    }
                    let buf = t.to_buffer(&self.client)?;
                    order.push(ArgSlot::Uploaded(uploaded.len()));
                    uploaded.push(buf);
                }
            }
        }
        let refs: Vec<&xla::PjRtBuffer> = order
            .iter()
            .map(|s| match s {
                ArgSlot::Pinned(k) => &self.resident[k],
                ArgSlot::Uploaded(i) => &uploaded[*i],
            })
            .collect();
        timing.upload_us = t0.elapsed_us();

        // Execute on the device.
        let t1 = Stopwatch::start();
        let mut results = entry
            .exe
            .execute_b(&refs)
            .map_err(|e| anyhow!("executing {entry_name}: {e:?}"))?;
        timing.execute_us = t1.elapsed_us();

        // Unpack. jax lowers with `return_tuple=True`, so PJRT hands back a
        // single tuple buffer; download it and split into the named outputs.
        let t2 = Stopwatch::start();
        let mut bufs = results.pop().ok_or_else(|| anyhow!("{entry_name}: empty result"))?;
        let root = if bufs.len() == 1 {
            bufs.pop().unwrap()
        } else {
            return Err(anyhow!("{entry_name}: expected 1 tuple result, got {}", bufs.len()));
        };
        let mut tuple = root
            .to_literal_sync()
            .map_err(|e| anyhow!("download result tuple: {e:?}"))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("decompose result tuple: {e:?}"))?;
        if parts.len() != entry.spec.outputs.len() {
            return Err(anyhow!(
                "{entry_name}: result arity {} != manifest outputs {}",
                parts.len(),
                entry.spec.outputs.len()
            ));
        }

        let mut host = BTreeMap::new();
        let mut device = BTreeMap::new();
        for (spec, lit) in entry.spec.outputs.iter().zip(parts) {
            if keep_on_device.contains(&spec.name.as_str()) {
                // Tuple results arrive on the host; re-upload to keep a
                // device-resident copy for chaining into the next call.
                // NB: must go through the typed host-buffer path, which is
                // a synchronous copy (kImmutableOnlyDuringCall); PJRT's
                // BufferFromHostLiteral is asynchronous and would read the
                // literal after we drop it (observed SIGSEGV).
                let t = HostTensor::from_literal(&lit, spec)?;
                let buf = t.to_buffer(&self.client)?;
                device.insert(spec.name.clone(), buf);
            } else {
                host.insert(spec.name.clone(), HostTensor::from_literal(&lit, spec)?);
            }
        }
        timing.download_us = t2.elapsed_us();

        Ok((ExecOutputs { host, device }, timing))
    }
}
