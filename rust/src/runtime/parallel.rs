//! Deterministic parallel kernel runtime + zero-alloc scratch arena.
//!
//! Two std-only building blocks the native backend's hot path is built on
//! (the offline image has no crates.io, so no rayon):
//!
//! * [`ThreadPool`] — a persistent worker pool whose one primitive,
//!   [`ThreadPool::par_partition`], splits `0..items` into at most
//!   `threads` contiguous ranges and runs a shared closure on each range
//!   concurrently. **Partition-only parallelism is the determinism
//!   contract:** every output element is computed by exactly one closure
//!   invocation, with the same ascending-index accumulation order it would
//!   see single-threaded. There are no cross-thread reductions, so
//!   `threads = 1` and `threads = N` produce bitwise-identical floats for
//!   any partition (see `native_numerics.rs`).
//! * [`ScratchArena`] — a free-list of reusable `Vec<f32>` buffers,
//!   zeroed on claim, that replaces the per-layer-per-step `vec![0.0; …]`
//!   churn in the native backend's forward/backward passes.
//!
//! The pool keeps `threads - 1` parked worker threads alive for the
//! lifetime of the owning backend; the calling thread always executes the
//! first chunk itself, so `threads = 1` costs nothing and never crosses a
//! thread boundary.

use std::marker::PhantomData;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Environment override for the default thread count (the CI test job
/// sets `LOQUETIER_THREADS=2`; the CLI's `--threads` flag wins over it).
pub const THREADS_ENV: &str = "LOQUETIER_THREADS";

/// Default worker count: the `LOQUETIER_THREADS` env var if set and valid,
/// else the machine's available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var(THREADS_ENV) {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a `--threads` request: `0` (the CLI default) means "auto"
/// ([`default_threads`]); anything else is taken literally.
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        default_threads()
    } else {
        requested
    }
}

type Job = dyn Fn(Range<usize>) + Sync;

thread_local! {
    /// True while this thread is executing a pool job. Submitting nested
    /// work from inside a worker closure would deadlock (the worker would
    /// wait on tasks queued behind its own), so `par_partition` rejects
    /// it in debug builds; keep kernel closures pool-free.
    static IN_POOL_JOB: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Erase a borrowed job's lifetime so parked workers can hold it.
///
/// # Safety
///
/// The caller must not let the returned reference outlive `job`. In
/// [`ThreadPool::par_partition`] this holds because the submitting thread
/// blocks on the completion latch (even on unwind, via `WaitGuard`) before
/// the frame owning the closure can be popped.
unsafe fn erase_job_lifetime(job: &Job) -> &'static Job {
    std::mem::transmute::<&Job, &'static Job>(job)
}

struct Task {
    job: &'static Job,
    range: Range<usize>,
    latch: Arc<Latch>,
}

/// Countdown latch: the submitter waits until every dispatched chunk has
/// finished (successfully or by panic).
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    fn new(n: usize) -> Self {
        Self { remaining: Mutex::new(n), done: Condvar::new(), panicked: AtomicBool::new(false) }
    }

    fn count_down(&self) {
        let mut r = self.remaining.lock().unwrap();
        *r -= 1;
        if *r == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = self.remaining.lock().unwrap();
        while *r > 0 {
            r = self.done.wait(r).unwrap();
        }
    }
}

/// Blocks on the latch when dropped — including during unwinding, so a
/// panicking caller chunk cannot free the shared closure while workers
/// still hold a reference to it.
struct WaitGuard<'a>(&'a Latch);

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.0.wait();
    }
}

/// Persistent scoped-work thread pool (see module docs for the
/// determinism contract).
pub struct ThreadPool {
    senders: Vec<Sender<Task>>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// Build a pool executing work on `threads` lanes total (the calling
    /// thread plus `threads - 1` parked workers). `0` is clamped to 1.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let mut senders = Vec::with_capacity(threads - 1);
        let mut handles = Vec::with_capacity(threads - 1);
        for w in 1..threads {
            let (tx, rx) = channel::<Task>();
            senders.push(tx);
            let handle = std::thread::Builder::new()
                .name(format!("loq-par-{w}"))
                .spawn(move || {
                    while let Ok(task) = rx.recv() {
                        let range = task.range.clone();
                        IN_POOL_JOB.with(|f| f.set(true));
                        let ok = catch_unwind(AssertUnwindSafe(|| (task.job)(range)));
                        IN_POOL_JOB.with(|f| f.set(false));
                        if ok.is_err() {
                            task.latch.panicked.store(true, Ordering::Release);
                        }
                        task.latch.count_down();
                    }
                })
                .expect("spawn pool worker");
            handles.push(handle);
        }
        Self { senders, handles, threads }
    }

    /// Total execution lanes (calling thread included).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Split `0..items` into at most `threads` balanced contiguous ranges
    /// and run `f` on each concurrently; returns when all are done.
    ///
    /// `f` must only write state that is owned by the range it was given —
    /// the partition-only determinism rule. With one lane (or one item)
    /// this is exactly `f(0..items)` on the calling thread.
    pub fn par_partition<F: Fn(Range<usize>) + Sync>(&self, items: usize, f: F) {
        if items == 0 {
            return;
        }
        let chunks = self.threads.min(items);
        let base = items / chunks;
        let rem = items % chunks;
        let range_of = |c: usize| {
            let start = c * base + c.min(rem);
            start..start + base + usize::from(c < rem)
        };
        self.dispatch(chunks, range_of, &f);
    }

    /// Weight-balanced variant: `prefix` is the cumulative per-item cost
    /// (`prefix.len() == items + 1`, `prefix[0] == 0`, strictly
    /// increasing). Lanes take contiguous item runs cut at equal shares
    /// of total cost, so a few expensive items cannot pin one lane —
    /// essential for causally-skewed attention units, whose cost grows
    /// with position. Lane assignment never changes per-element
    /// accumulation order, so determinism is unaffected by the weighting.
    pub fn par_partition_weighted<F: Fn(Range<usize>) + Sync>(&self, prefix: &[usize], f: F) {
        debug_assert!(!prefix.is_empty());
        let items = prefix.len() - 1;
        if items == 0 {
            return;
        }
        let total = prefix[items];
        let lanes = self.threads.min(items);
        let cut = |lane: usize| -> usize {
            let target = lane * total / lanes;
            prefix.partition_point(|&p| p < target).min(items)
        };
        self.dispatch(lanes, |lane| cut(lane)..cut(lane + 1), &f);
    }

    /// Shared dispatch tail: run `f` over `range_of(0..chunks)`, chunk 0
    /// on the calling thread, the rest on parked workers.
    fn dispatch<F, R>(&self, chunks: usize, range_of: R, f: &F)
    where
        F: Fn(Range<usize>) + Sync,
        R: Fn(usize) -> Range<usize>,
    {
        debug_assert!(
            !IN_POOL_JOB.with(|flag| flag.get()),
            "nested pool dispatch from inside a pool job would deadlock"
        );
        if chunks <= 1 {
            if chunks == 1 {
                f(range_of(0));
            }
            return;
        }
        let job: &Job = f;
        // SAFETY: the guard below blocks until every worker finished this
        // job before the current frame (and `f`) can unwind away.
        let job = unsafe { erase_job_lifetime(job) };
        let latch = Arc::new(Latch::new(chunks - 1));
        {
            let _guard = WaitGuard(&latch);
            for c in 1..chunks {
                let task = Task { job, range: range_of(c), latch: Arc::clone(&latch) };
                self.senders[c - 1].send(task).expect("pool worker alive");
            }
            f(range_of(0));
            // _guard drops here: wait for the dispatched chunks.
        }
        if latch.panicked.load(Ordering::Acquire) {
            panic!("worker panicked inside par_partition");
        }
    }

    /// Row-partitioned variant: split `buf` (logically `rows × row_len`)
    /// into contiguous row ranges and hand each closure its range plus the
    /// matching mutable sub-slice.
    pub fn par_rows<T, F>(&self, buf: &mut [T], rows: usize, row_len: usize, f: F)
    where
        T: Send,
        F: Fn(Range<usize>, &mut [T]) + Sync,
    {
        debug_assert_eq!(buf.len(), rows * row_len);
        let shared = SharedSliceMut::new(buf);
        self.par_partition(rows, |r| {
            // SAFETY: par_partition ranges are disjoint, so the row
            // sub-slices are too.
            let rows_slice = unsafe { shared.slice(r.start * row_len, r.len() * row_len) };
            f(r, rows_slice);
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Closing the channels pops every worker out of `recv`.
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A mutable slice shareable across pool workers for partition-only
/// writes. The *user* guarantees disjointness; the type only carries the
/// pointer and the lifetime.
pub struct SharedSliceMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _lt: PhantomData<&'a mut T>,
}

// SAFETY: access is gated through the `unsafe fn slice`, whose contract
// demands disjoint ranges across concurrent users; `T: Send` suffices
// because each element is only ever touched from one thread at a time.
unsafe impl<T: Send> Send for SharedSliceMut<'_, T> {}
// SAFETY: sharing `&SharedSliceMut` across threads only exposes `unsafe
// fn slice`, whose disjoint-range contract already forbids two threads
// touching the same element — so shared references add no new access.
unsafe impl<T: Send> Sync for SharedSliceMut<'_, T> {}

impl<'a, T> SharedSliceMut<'a, T> {
    pub fn new(data: &'a mut [T]) -> Self {
        Self { ptr: data.as_mut_ptr(), len: data.len(), _lt: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Reborrow `[start, start + len)` mutably.
    ///
    /// # Safety
    ///
    /// The range must be in bounds, and no two concurrent `slice` calls
    /// (nor any other live borrow of the underlying data) may overlap it.
    #[allow(clippy::mut_from_ref)] // partition-only parallel write window
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

/// Free-list of reusable `Vec<f32>` scratch buffers, zeroed on claim.
///
/// The native backend owns one and threads it through every forward /
/// backward pass: [`take`](ScratchArena::take) hands out a zeroed buffer
/// of the requested length (reusing the best-fitting retired allocation),
/// [`give`](ScratchArena::give) retires a buffer back to the pool. After
/// the first step of each shape the hot path performs no heap allocation
/// for activations, gradients, payloads or logits. Retention is capped
/// (64 MiB of f32 by default): buffers whose return would push the
/// retained total past the limit are dropped instead, so one outlier
/// launch cannot pin its peak scratch for the backend's lifetime.
pub struct ScratchArena {
    free: Vec<Vec<f32>>,
    /// Total f32 capacity currently parked in `free`.
    retained: usize,
    /// High-water limit on `retained`.
    retain_limit: usize,
}

/// Default retention cap: 2^24 f32 elements = 64 MiB of scratch.
const DEFAULT_RETAIN_LIMIT: usize = 1 << 24;

impl Default for ScratchArena {
    fn default() -> Self {
        Self::new()
    }
}

impl ScratchArena {
    pub fn new() -> Self {
        Self::with_retain_limit(DEFAULT_RETAIN_LIMIT)
    }

    /// An arena that parks at most `limit` f32 elements of retired
    /// capacity.
    pub fn with_retain_limit(limit: usize) -> Self {
        Self { free: Vec::new(), retained: 0, retain_limit: limit }
    }

    /// Claim a zeroed buffer of exactly `len` elements.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        // Best fit: the smallest retired capacity that already holds
        // `len`; else the largest (which `resize` then grows in place).
        let mut pick: Option<usize> = None;
        for (i, buf) in self.free.iter().enumerate() {
            let c = buf.capacity();
            pick = match pick {
                None => Some(i),
                Some(p) => {
                    let pc = self.free[p].capacity();
                    let better = if pc >= len { c >= len && c < pc } else { c > pc };
                    Some(if better { i } else { p })
                }
            };
        }
        let mut buf = match pick {
            Some(i) => {
                let b = self.free.swap_remove(i);
                self.retained -= b.capacity();
                b
            }
            None => Vec::new(),
        };
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// Retire a buffer for reuse by a later [`take`](ScratchArena::take).
    /// Dropped instead when it would push retained capacity past the
    /// limit.
    pub fn give(&mut self, buf: Vec<f32>) {
        let cap = buf.capacity();
        if cap > 0 && self.retained + cap <= self.retain_limit {
            self.retained += cap;
            self.free.push(buf);
        }
    }

    /// Number of retired buffers currently pooled.
    pub fn pooled(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn par_partition_covers_every_index_exactly_once() {
        let pool = ThreadPool::new(4);
        for items in [0usize, 1, 3, 4, 5, 17, 100] {
            let hits: Vec<AtomicUsize> = (0..items).map(|_| AtomicUsize::new(0)).collect();
            pool.par_partition(items, |r| {
                for i in r {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "items={items}: every index hit exactly once"
            );
        }
    }

    #[test]
    fn par_partition_weighted_covers_every_index_exactly_once() {
        let pool = ThreadPool::new(4);
        // Causal-attention-shaped weights: cost grows with index.
        for items in [1usize, 2, 5, 33] {
            let mut prefix = vec![0usize];
            for i in 0..items {
                prefix.push(prefix.last().unwrap() + i + 1);
            }
            let hits: Vec<AtomicUsize> = (0..items).map(|_| AtomicUsize::new(0)).collect();
            pool.par_partition_weighted(&prefix, |r| {
                for i in r {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "items={items}: every index hit exactly once"
            );
        }
    }

    #[test]
    fn weighted_cuts_balance_skewed_costs() {
        // 33 items with triangular weights on 4 lanes: the heaviest lane
        // must carry well under the ~50% an unweighted index split would
        // give the tail lane. Measure via the per-lane weight sums.
        let pool = ThreadPool::new(4);
        let items = 33usize;
        let mut prefix = vec![0usize];
        for i in 0..items {
            prefix.push(prefix.last().unwrap() + i + 1);
        }
        let total = *prefix.last().unwrap();
        let lane_loads = std::sync::Mutex::new(Vec::new());
        pool.par_partition_weighted(&prefix, |r| {
            let load: usize = r.map(|i| i + 1).sum();
            lane_loads.lock().unwrap().push(load);
        });
        let max_load = *lane_loads.lock().unwrap().iter().max().unwrap();
        assert!(
            max_load * 10 <= total * 4,
            "heaviest lane {max_load} of {total} exceeds 40%"
        );
    }

    #[test]
    fn par_rows_hands_out_disjoint_row_blocks() {
        let pool = ThreadPool::new(3);
        let (rows, row_len) = (7, 5);
        let mut buf = vec![0.0f32; rows * row_len];
        pool.par_rows(&mut buf, rows, row_len, |r, rs| {
            for (ti, row) in r.clone().zip(rs.chunks_mut(row_len)) {
                row.iter_mut().for_each(|v| *v = ti as f32);
            }
        });
        for t in 0..rows {
            assert!(buf[t * row_len..(t + 1) * row_len].iter().all(|&v| v == t as f32));
        }
    }

    // GEMM thread-invariance now lives with the unified kernel:
    // `kernels::tests::gemm_is_bitwise_thread_count_invariant` runs the
    // blocked path at t ∈ {1,2,4,8} per layout against the serial result.

    #[test]
    #[should_panic(expected = "worker panicked inside par_partition")]
    fn worker_panic_propagates_to_submitter() {
        let pool = ThreadPool::new(4);
        pool.par_partition(4, |r| {
            // Panic on a worker chunk (not the caller's chunk 0).
            if r.start > 0 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn pool_survives_a_panicked_job() {
        let pool = ThreadPool::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.par_partition(4, |r| {
                if r.start > 0 {
                    panic!("boom");
                }
            })
        }));
        assert!(r.is_err());
        // Workers are still parked and serving.
        let hits: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        pool.par_partition(8, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn scratch_arena_zeroes_on_claim_and_reuses_allocations() {
        let mut arena = ScratchArena::new();
        let mut b = arena.take(64);
        b.iter_mut().for_each(|v| *v = 7.5);
        let ptr = b.as_ptr();
        arena.give(b);

        // Smaller claim reuses the retired allocation — and sees zeros.
        let c = arena.take(16);
        assert_eq!(c.as_ptr(), ptr, "retired allocation is reused");
        assert!(c.iter().all(|&v| v == 0.0), "claimed buffer is zeroed");
        arena.give(c);

        // Larger claim also comes back fully zeroed.
        let d = arena.take(128);
        assert_eq!(d.len(), 128);
        assert!(d.iter().all(|&v| v == 0.0));
        arena.give(d);
        assert_eq!(arena.pooled(), 1);
    }

    #[test]
    fn scratch_arena_retain_limit_drops_excess() {
        let mut arena = ScratchArena::with_retain_limit(100);
        let b1 = arena.take(60);
        let b2 = arena.take(60);
        arena.give(b1);
        assert_eq!(arena.pooled(), 1, "first buffer fits under the limit");
        arena.give(b2);
        assert_eq!(arena.pooled(), 1, "second would exceed the limit and is dropped");
        // Taking the parked buffer frees headroom again.
        let b = arena.take(60);
        assert_eq!(arena.pooled(), 0);
        arena.give(b);
        assert_eq!(arena.pooled(), 1);
    }

    #[test]
    fn resolve_threads_zero_means_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }
}
