//! Host-side tensors: the lingua franca between the coordinator and PJRT.

use anyhow::{anyhow, Result};

use super::TensorSpec;

/// Element type. Only the two dtypes the AOT contract uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        4
    }

    pub(crate) fn element_type(self) -> xla::ElementType {
        match self {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
        }
    }
}

/// A dense host tensor (row-major).
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    data: Data,
}

#[derive(Debug, Clone)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn f32(shape: impl Into<Vec<usize>>, data: Vec<f32>) -> Result<Self> {
        let shape = shape.into();
        let n: usize = shape.iter().product::<usize>().max(1);
        if data.len() != n {
            return Err(anyhow!("f32 tensor: {} elems for shape {:?}", data.len(), shape));
        }
        Ok(Self { dtype: DType::F32, shape, data: Data::F32(data) })
    }

    pub fn i32(shape: impl Into<Vec<usize>>, data: Vec<i32>) -> Result<Self> {
        let shape = shape.into();
        let n: usize = shape.iter().product::<usize>().max(1);
        if data.len() != n {
            return Err(anyhow!("i32 tensor: {} elems for shape {:?}", data.len(), shape));
        }
        Ok(Self { dtype: DType::I32, shape, data: Data::I32(data) })
    }

    pub fn zeros(spec: &TensorSpec) -> Self {
        let n = spec.element_count();
        match spec.dtype {
            DType::F32 => Self {
                dtype: DType::F32,
                shape: spec.shape.clone(),
                data: Data::F32(vec![0.0; n]),
            },
            DType::I32 => Self {
                dtype: DType::I32,
                shape: spec.shape.clone(),
                data: Data::I32(vec![0; n]),
            },
        }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self { dtype: DType::F32, shape: vec![], data: Data::F32(vec![v]) }
    }

    pub fn scalar_i32(v: i32) -> Self {
        Self { dtype: DType::I32, shape: vec![], data: Data::I32(vec![v]) }
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => Err(anyhow!("tensor is i32, not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            Data::F32(_) => Err(anyhow!("tensor is f32, not i32")),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => Err(anyhow!("tensor is i32, not f32")),
        }
    }

    pub fn as_i32_mut(&mut self) -> Result<&mut [i32]> {
        match &mut self.data {
            Data::I32(v) => Ok(v),
            Data::F32(_) => Err(anyhow!("tensor is f32, not i32")),
        }
    }

    fn raw_bytes(&self) -> &[u8] {
        match &self.data {
            Data::F32(v) => bytemuck_cast(v),
            Data::I32(v) => bytemuck_cast_i32(v),
        }
    }

    /// Convert to an XLA literal (one copy).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        xla::Literal::create_from_shape_and_untyped_data(
            self.dtype.element_type(),
            &self.shape,
            self.raw_bytes(),
        )
        .map_err(|e| anyhow!("literal create: {e:?}"))
    }

    /// Upload straight to a device buffer (skips the literal copy).
    ///
    /// NB: goes through the *typed* `buffer_from_host_buffer::<T>` — the
    /// crate's raw-bytes variant passes the ElementType ordinal where the C
    /// API expects a PrimitiveType, silently producing an F16 buffer for
    /// F32 data.
    pub fn to_buffer(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        match &self.data {
            Data::F32(v) => client
                .buffer_from_host_buffer::<f32>(v, &self.shape, None)
                .map_err(|e| anyhow!("buffer upload: {e:?}")),
            Data::I32(v) => client
                .buffer_from_host_buffer::<i32>(v, &self.shape, None)
                .map_err(|e| anyhow!("buffer upload: {e:?}")),
        }
    }

    /// Copy an XLA literal back to the host, checked against `spec`.
    pub fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Self> {
        let n = spec.element_count();
        match spec.dtype {
            DType::F32 => {
                let mut v = vec![0f32; n];
                lit.copy_raw_to(&mut v).map_err(|e| anyhow!("literal read: {e:?}"))?;
                HostTensor::f32(spec.shape.clone(), v)
            }
            DType::I32 => {
                let mut v = vec![0i32; n];
                lit.copy_raw_to(&mut v).map_err(|e| anyhow!("literal read: {e:?}"))?;
                HostTensor::i32(spec.shape.clone(), v)
            }
        }
    }
}

fn bytemuck_cast(v: &[f32]) -> &[u8] {
    // SAFETY: any initialized f32 slice is viewable as bytes — u8 has
    // alignment 1, the length `len * 4` covers exactly the same
    // allocation, and the borrow ties the view to `v`'s lifetime.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn bytemuck_cast_i32(v: &[i32]) -> &[u8] {
    // SAFETY: same argument as `bytemuck_cast` — i32 → u8 view over the
    // identical allocation, `len * 4` bytes, lifetime-bound to `v`.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_mismatch_rejected() {
        assert!(HostTensor::f32(vec![2, 2], vec![0.0; 3]).is_err());
        assert!(HostTensor::i32(vec![4], vec![1, 2, 3, 4]).is_ok());
    }

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar_f32(2.5);
        assert_eq!(t.element_count(), 1);
        assert_eq!(t.as_f32().unwrap(), &[2.5]);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        let lit = t.to_literal().unwrap();
        let spec = TensorSpec { name: "x".into(), shape: vec![2, 3], dtype: DType::F32 };
        let back = HostTensor::from_literal(&lit, &spec).unwrap();
        assert_eq!(back.as_f32().unwrap(), t.as_f32().unwrap());
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::i32(vec![4], vec![7, -1, 0, 42]).unwrap();
        let lit = t.to_literal().unwrap();
        let spec = TensorSpec { name: "x".into(), shape: vec![4], dtype: DType::I32 };
        let back = HostTensor::from_literal(&lit, &spec).unwrap();
        assert_eq!(back.as_i32().unwrap(), t.as_i32().unwrap());
    }
}
