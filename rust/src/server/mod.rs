//! JSON-lines TCP frontend: the adapter-lifecycle serving path.
//!
//! Thread-per-connection over std::net (the offline environment has no
//! tokio; the engine loop is single-threaded over the backend anyway, so
//! async buys nothing here). Connection threads parse and frame; a single
//! [`engine_loop`] owns the coordinator, the backend and the adapter
//! directory, so every registry mutation is serialized with step launches —
//! the paper's hot-swap guarantee (a load/unload between steps is one bank
//! write + lazy upload; the computation flow never halts).
//!
//! Wire protocol (one JSON object per line; see README.md for the full
//! reference):
//!
//! ```text
//! -> {"op":"generate","prompt":"...","model":"vm0","max_new_tokens":32}
//! <- {"id":7,"text":"...","tokens":[...],"latency_s":0.42}
//!
//! -> {"op":"generate","prompt":"...","model":"vm0","stream":true}
//! <- {"id":8,"index":0,"token":17,"text":"a"}        (one frame per token)
//! <- {"id":8,"index":1,"token":4,"text":"b"}
//! <- {"id":8,"done":true,"text":"ab","tokens":[17,4],"latency_s":0.9}
//!
//! -> {"op":"load_adapter","name":"vm9","index":2}    (or "path":"ad.json")
//! <- {"ok":true,"name":"vm9","slot":2}
//! -> {"op":"unload_adapter","name":"vm9"}
//! <- {"ok":true,"name":"vm9","slot":2}
//! -> {"op":"list_adapters"}
//! <- {"adapters":[{"name":"vm0","slot":0,"state":"inference","rank":8}]}
//!
//! -> {"op":"stats"}
//! <- {"queued":0,"active":1,...,"per_adapter":{"vm0":{...}}}
//! -> {"op":"shutdown"}                               (drain, then ack)
//! <- {"ok":true,"drained":true}
//! ```
//!
//! Error frames carry a typed code — snake_case `err` name plus the
//! numeric HTTP-flavored `code` existing clients already switch on (see
//! [`ErrCode`] and the README wire reference):
//! `{"error":"overloaded","err":"overloaded","code":503,"retry_after_ms":400}`.
//! Admission is bounded globally and per adapter (fair share), so one hot
//! tenant cannot starve the rest of the bank; 503 rejects include a
//! deterministic `retry_after_ms` backoff hint scaled by instantaneous
//! load. Client sockets carry read/write timeouts
//! ([`Frontend::set_conn_timeout_ms`]) so half-open connections are
//! reclaimed instead of pinning a thread forever.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::coordinator::{Coordinator, InferenceRequest};
use crate::engine::Backend;
use crate::metrics::{AdapterCounters, GaugeSeries, LatencySummary};
use crate::model::{LoraAdapter, SlotState, VirtualizedRegistry, WeightStore};
use crate::runtime::Manifest;
use crate::util::bench::Stopwatch;
use crate::util::json::{self, Json};

// --------------------------------------------------------------------------
// Wire protocol
// --------------------------------------------------------------------------

/// Where a `load_adapter` op takes its weights from.
#[derive(Debug, Clone, PartialEq)]
pub enum AdapterSource {
    /// `adapter{index}.*` records in the AOT weight store.
    StoreIndex(usize),
    /// A JSON adapter file ([`LoraAdapter::save`] format) on the server.
    Path(String),
    /// Zero-initialized adapter (a fresh slot, e.g. to fine-tune into).
    Blank,
}

/// Per-request SLO overrides carried on a `generate` op (DESIGN.md §9):
/// any subset of the three bounds; unset bounds inherit the deployment's
/// configured spec. The SLO-aware scheduler plans admission order, decode
/// urgency and fine-tune headroom from these, and the live attainment
/// tracker judges the request against them.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SloOverride {
    pub max_waiting_s: Option<f64>,
    pub mean_decode_s: Option<f64>,
    pub max_decode_s: Option<f64>,
}

impl SloOverride {
    fn parse(v: &Json) -> Self {
        let f = |k: &str| v.get(k).and_then(|x| x.as_f64().ok()).filter(|x| *x >= 0.0);
        Self {
            max_waiting_s: f("slo_max_waiting_s"),
            mean_decode_s: f("slo_mean_decode_s"),
            max_decode_s: f("slo_max_decode_s"),
        }
    }

    fn is_set(&self) -> bool {
        self.max_waiting_s.is_some() || self.mean_decode_s.is_some() || self.max_decode_s.is_some()
    }

    /// Resolve against the deployment default: `None` when nothing was
    /// overridden (the request inherits whatever the coordinator runs).
    pub fn resolve(&self, default: &crate::metrics::SloSpec) -> Option<crate::metrics::SloSpec> {
        if !self.is_set() {
            return None;
        }
        Some(crate::metrics::SloSpec {
            max_waiting_s: self.max_waiting_s.unwrap_or(default.max_waiting_s),
            mean_decode_latency_s: self.mean_decode_s.unwrap_or(default.mean_decode_latency_s),
            max_decode_latency_s: self.max_decode_s.unwrap_or(default.max_decode_latency_s),
        })
    }
}

/// A parsed client message.
#[derive(Debug)]
pub enum ClientMsg {
    Generate {
        prompt: String,
        model: Option<String>,
        max_new_tokens: usize,
        stream: bool,
        slo: SloOverride,
    },
    LoadAdapter {
        name: String,
        slot: Option<usize>,
        source: AdapterSource,
    },
    UnloadAdapter {
        name: String,
    },
    ListAdapters,
    Stats,
    Shutdown,
}

/// Hard cap on a single request's generation length (protocol-level sanity
/// bound; the KV slot capacity is the real limit and is config-dependent).
pub const MAX_NEW_TOKENS_CAP: usize = 4096;

impl ClientMsg {
    pub fn parse(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        match v.req("op")?.as_str()? {
            "generate" => Ok(ClientMsg::Generate {
                prompt: v.req("prompt")?.as_str()?.to_string(),
                model: v.get("model").and_then(|m| m.as_str().ok()).map(String::from),
                max_new_tokens: v
                    .get("max_new_tokens")
                    .and_then(|n| n.as_usize().ok())
                    .unwrap_or(32)
                    .clamp(1, MAX_NEW_TOKENS_CAP),
                stream: v.get("stream").and_then(|b| b.as_bool().ok()).unwrap_or(false),
                slo: SloOverride::parse(&v),
            }),
            "load_adapter" => {
                let name = v.req("name")?.as_str()?.to_string();
                let slot = match v.get("slot") {
                    Some(s) => Some(s.as_usize()?),
                    None => None,
                };
                let source = if let Some(p) = v.get("path") {
                    AdapterSource::Path(p.as_str()?.to_string())
                } else if let Some(i) = v.get("index") {
                    AdapterSource::StoreIndex(i.as_usize()?)
                } else {
                    AdapterSource::Blank
                };
                Ok(ClientMsg::LoadAdapter { name, slot, source })
            }
            "unload_adapter" => Ok(ClientMsg::UnloadAdapter {
                name: v.req("name")?.as_str()?.to_string(),
            }),
            "list_adapters" => Ok(ClientMsg::ListAdapters),
            "stats" => Ok(ClientMsg::Stats),
            "shutdown" => Ok(ClientMsg::Shutdown),
            other => anyhow::bail!("unknown op '{other}'"),
        }
    }
}

/// Typed wire error codes: every error frame carries both the numeric
/// `code` (HTTP-flavored, stable for existing clients) and the snake_case
/// `err` name so scripts can switch on a string instead of a magic number.
///
/// | name          | code | meaning                                       |
/// |---------------|------|-----------------------------------------------|
/// | `bad_request` | 400  | malformed op / unknown model / over capacity  |
/// | `conflict`    | 409  | adapter lifecycle conflict (busy, duplicate)  |
/// | `quarantined` | 422  | request isolated after repeated step failures |
/// | `internal`    | 500  | engine loop gone or internal failure          |
/// | `overloaded`  | 503  | admission reject / draining / queue timeout   |
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrCode {
    BadRequest,
    Conflict,
    Quarantined,
    Internal,
    Overloaded,
}

impl ErrCode {
    pub fn code(self) -> u64 {
        match self {
            ErrCode::BadRequest => 400,
            ErrCode::Conflict => 409,
            ErrCode::Quarantined => 422,
            ErrCode::Internal => 500,
            ErrCode::Overloaded => 503,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ErrCode::BadRequest => "bad_request",
            ErrCode::Conflict => "conflict",
            ErrCode::Quarantined => "quarantined",
            ErrCode::Internal => "internal",
            ErrCode::Overloaded => "overloaded",
        }
    }
}

// --------------------------------------------------------------------------
// Stats
// --------------------------------------------------------------------------

/// Serving statistics exposed over the wire.
#[derive(Debug, Default, Clone)]
pub struct Stats {
    pub queued: usize,
    pub active: usize,
    pub completed: usize,
    pub decode_tokens: u64,
    pub finetune_tokens: u64,
    /// Requests refused at admission (backpressure / draining / unknown).
    pub rejected: u64,
    /// Adapters currently resident in the bank.
    pub loaded_adapters: usize,
    /// Total preempt-and-recompute events over the run (a decode step ran
    /// out of KV blocks and the youngest active request yielded).
    pub preemptions: u64,
    /// KV block-pool occupancy (on-demand paging ledger).
    pub kv_blocks_used: usize,
    pub kv_blocks_total: usize,
    /// Reserved-but-unused KV token capacity (internal fragmentation —
    /// block rounding under paging; worst-case headroom in the ablation),
    /// instantaneous and run-peak.
    pub kv_frag_tokens: usize,
    pub kv_frag_peak_tokens: usize,
    /// Shared-prefix KV reuse (DESIGN.md §14): admissions that attached to
    /// cached prefix blocks, the prompt tokens those hits removed from the
    /// prefill plan, and how many prefix-index blocks live slots currently
    /// reference. All zero with `prefix_sharing` off.
    pub prefix_hits: u64,
    pub prefill_tokens_saved: u64,
    pub kv_blocks_shared: usize,
    /// Unified adapter paging (DESIGN.md §10): total host↔device swap
    /// events so far, and where known adapters currently sit — resident
    /// in the device bank vs parked in the host tier. All zero when
    /// paging is inactive (no finite `adapter_budget` configured).
    pub adapter_swaps: u64,
    pub adapter_resident: usize,
    pub adapter_host: usize,
    /// Live SLO attainment: fraction of terminal requests that met their
    /// SLO, tracked by the scheduler as it runs (1.0 while nothing has
    /// finished). DESIGN.md §9.
    pub slo_attainment: f64,
    /// Fault-supervision counters (DESIGN.md §12): faults the backend
    /// injected (0 outside chaos runs), step retries the supervisor
    /// absorbed, requests quarantined after per-row isolation, durable
    /// adapter checkpoints written, and full backend resets recovered
    /// via preempt-and-recompute.
    pub faults_injected: u64,
    pub step_retries: u64,
    pub quarantined: u64,
    pub checkpoints_written: u64,
    pub backend_resets: u64,
    /// Per-virtual-model counters, keyed by model name ("" = base model).
    pub per_adapter: BTreeMap<String, AdapterCounters>,
    /// Per-virtual-model TTFT/TPOT quantiles (interpolated
    /// `LatencyHistogram::quantile`), same keying as `per_adapter`; only
    /// models with at least one latency sample appear.
    pub per_adapter_latency: BTreeMap<String, LatencySummary>,
    /// Engine queue depth over time (queued + preempted +
    /// admitted-not-finished).
    pub queue_depth: GaugeSeries,
}

impl Stats {
    fn to_json(&self) -> Json {
        // Union of counter and latency keys: a model that has only
        // latency samples (or only counters) still gets one object.
        let names: Vec<&String> = {
            let mut v: Vec<&String> =
                self.per_adapter.keys().chain(self.per_adapter_latency.keys()).collect();
            v.sort();
            v.dedup();
            v
        };
        let per_adapter = Json::Obj(
            names
                .into_iter()
                .map(|name| {
                    let c = self.per_adapter.get(name).copied().unwrap_or_default();
                    let mut kvs = vec![
                        ("submitted", Json::Num(c.submitted as f64)),
                        ("completed", Json::Num(c.completed as f64)),
                        ("rejected", Json::Num(c.rejected as f64)),
                        ("decode_tokens", Json::Num(c.decode_tokens as f64)),
                    ];
                    if let Some(l) = self.per_adapter_latency.get(name) {
                        kvs.push(("ttft_p50_s", Json::Num(l.ttft_p50_s)));
                        kvs.push(("ttft_p99_s", Json::Num(l.ttft_p99_s)));
                        kvs.push(("tpot_p50_s", Json::Num(l.tpot_p50_s)));
                        kvs.push(("tpot_p99_s", Json::Num(l.tpot_p99_s)));
                    }
                    (name.clone(), Json::obj(kvs))
                })
                .collect(),
        );
        Json::obj(vec![
            ("queued", Json::Num(self.queued as f64)),
            ("active", Json::Num(self.active as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("decode_tokens", Json::Num(self.decode_tokens as f64)),
            ("finetune_tokens", Json::Num(self.finetune_tokens as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("loaded_adapters", Json::Num(self.loaded_adapters as f64)),
            ("preemptions", Json::Num(self.preemptions as f64)),
            ("kv_blocks_used", Json::Num(self.kv_blocks_used as f64)),
            ("kv_blocks_total", Json::Num(self.kv_blocks_total as f64)),
            ("kv_frag_tokens", Json::Num(self.kv_frag_tokens as f64)),
            ("kv_frag_peak_tokens", Json::Num(self.kv_frag_peak_tokens as f64)),
            ("prefix_hits", Json::Num(self.prefix_hits as f64)),
            ("prefill_tokens_saved", Json::Num(self.prefill_tokens_saved as f64)),
            ("kv_blocks_shared", Json::Num(self.kv_blocks_shared as f64)),
            ("adapter_swaps", Json::Num(self.adapter_swaps as f64)),
            ("adapter_resident", Json::Num(self.adapter_resident as f64)),
            ("adapter_host", Json::Num(self.adapter_host as f64)),
            ("slo_attainment", Json::Num(self.slo_attainment)),
            ("faults_injected", Json::Num(self.faults_injected as f64)),
            ("step_retries", Json::Num(self.step_retries as f64)),
            ("quarantined", Json::Num(self.quarantined as f64)),
            ("checkpoints_written", Json::Num(self.checkpoints_written as f64)),
            ("backend_resets", Json::Num(self.backend_resets as f64)),
            ("queue_depth", Json::Num(self.queue_depth.last().map(|(_, v)| v).unwrap_or(0.0))),
            ("queue_depth_max", Json::Num(self.queue_depth.max())),
            ("per_adapter", per_adapter),
        ])
    }
}

// --------------------------------------------------------------------------
// Engine messages
// --------------------------------------------------------------------------

/// Incremental events the engine sends back per generation.
#[derive(Debug)]
pub enum TokenEvent {
    /// One decoded token (streaming frame `index` = 0-based position).
    Token { index: usize, token: i32 },
    /// Terminal: the full output.
    Done { tokens: Vec<i32>, latency_s: f64 },
    /// Terminal: the request failed, with a typed wire code.
    Error { code: ErrCode, msg: String },
}

/// A generation handed from a connection thread to the engine loop.
pub struct GenerateJob {
    pub id: u64,
    pub model: Option<String>,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Per-request SLO overrides (resolved against the coordinator's
    /// default spec at submit time).
    pub slo: SloOverride,
    pub events: Sender<TokenEvent>,
}

/// Adapter-lifecycle operations (serialized with step launches).
#[derive(Debug)]
pub enum ControlOp {
    Load { name: String, slot: Option<usize>, source: AdapterSource },
    Unload { name: String },
    List,
}

#[derive(Debug, Clone)]
pub struct AdapterInfo {
    pub name: String,
    pub slot: usize,
    pub state: &'static str,
    pub rank: usize,
}

impl AdapterInfo {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("slot", Json::Num(self.slot as f64)),
            ("state", Json::Str(self.state.to_string())),
            ("rank", Json::Num(self.rank as f64)),
        ])
    }
}

#[derive(Debug)]
pub enum ControlReply {
    Loaded { name: String, slot: usize },
    Unloaded { name: String, slot: usize },
    Adapters(Vec<AdapterInfo>),
    Err(String),
}

pub struct ControlMsg {
    pub op: ControlOp,
    pub reply: Sender<ControlReply>,
}

/// Everything a connection thread can send the engine loop.
pub enum EngineMsg {
    Generate(GenerateJob),
    Control(ControlMsg),
    /// Graceful shutdown: stop admitting, drain in-flight generations, then
    /// exit the engine loop. The reply fires once drained.
    Shutdown { reply: Sender<()> },
}

// --------------------------------------------------------------------------
// Admission control
// --------------------------------------------------------------------------

/// Bounded-queue admission with per-adapter fairness.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Max generations in flight (engine queue + active) across all models.
    pub max_inflight: usize,
    /// Per-model fair-share cap, so one hot tenant cannot occupy the whole
    /// queue while other adapters' traffic gets 503s.
    pub max_inflight_per_adapter: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self { max_inflight: 64, max_inflight_per_adapter: 16 }
    }
}

#[derive(Default)]
struct Inflight {
    total: usize,
    // Ordered so any future dump of per-model occupancy is stable.
    per_model: BTreeMap<String, usize>,
}

/// Default per-socket read/write timeout ([`Frontend::set_conn_timeout_ms`]).
pub const DEFAULT_CONN_TIMEOUT_MS: u64 = 60_000;

/// Shared state between connection threads and the engine loop.
pub struct Frontend {
    tx: Mutex<Sender<EngineMsg>>,
    pub stats: Arc<Mutex<Stats>>,
    pub admission: AdmissionConfig,
    inflight: Mutex<Inflight>,
    draining: AtomicBool,
    next_id: AtomicU64,
    conn_timeout_ms: AtomicU64,
}

/// Admission token: releases its in-flight reservation exactly once, on
/// drop — whichever way the per-request block exits (done, error, write
/// failure, engine gone).
pub struct AdmitGuard {
    fe: Arc<Frontend>,
    key: String,
}

impl std::fmt::Debug for AdmitGuard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AdmitGuard({:?})", self.key)
    }
}

impl Drop for AdmitGuard {
    fn drop(&mut self) {
        let mut inf = match self.fe.inflight.lock() {
            Ok(i) => i,
            Err(_) => return,
        };
        inf.total = inf.total.saturating_sub(1);
        let emptied = match inf.per_model.get_mut(&self.key) {
            Some(n) => {
                *n = n.saturating_sub(1);
                *n == 0
            }
            None => false,
        };
        if emptied {
            inf.per_model.remove(&self.key);
        }
    }
}

impl Frontend {
    pub fn new(admission: AdmissionConfig) -> (Arc<Self>, Receiver<EngineMsg>) {
        let (tx, rx) = channel();
        (
            Arc::new(Self {
                tx: Mutex::new(tx),
                stats: Arc::new(Mutex::new(Stats::default())),
                admission,
                inflight: Mutex::new(Inflight::default()),
                draining: AtomicBool::new(false),
                next_id: AtomicU64::new(1),
                conn_timeout_ms: AtomicU64::new(DEFAULT_CONN_TIMEOUT_MS),
            }),
            rx,
        )
    }

    pub fn next_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Send a message to the engine loop.
    pub fn send(&self, msg: EngineMsg) -> Result<()> {
        self.tx
            .lock()
            .map_err(|_| anyhow!("frontend poisoned"))?
            .send(msg)
            .map_err(|_| anyhow!("engine loop gone"))
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Relaxed)
    }

    pub fn set_draining(&self) {
        self.draining.store(true, Ordering::Relaxed);
    }

    /// Try to reserve an in-flight slot for `key` (model name, "" = base).
    /// Returns the reason string on refusal.
    pub fn try_admit(self: &Arc<Self>, key: &str) -> std::result::Result<AdmitGuard, String> {
        if self.is_draining() {
            return Err("draining".to_string());
        }
        let mut inf = self.inflight.lock().map_err(|_| "frontend poisoned".to_string())?;
        if inf.total >= self.admission.max_inflight {
            return Err("overloaded".to_string());
        }
        let n = inf.per_model.entry(key.to_string()).or_insert(0);
        if *n >= self.admission.max_inflight_per_adapter {
            return Err(format!("model '{key}' over fair-share limit"));
        }
        *n += 1;
        inf.total += 1;
        Ok(AdmitGuard { fe: self.clone(), key: key.to_string() })
    }

    pub fn inflight(&self) -> usize {
        self.inflight.lock().map(|i| i.total).unwrap_or(0)
    }

    /// Per-socket read/write timeout applied to every connection in
    /// [`handle_conn`]: a half-open client (gone without FIN, or one that
    /// stops draining its socket) is reclaimed after this long instead of
    /// pinning a connection thread forever. 0 disables the timeout.
    pub fn set_conn_timeout_ms(&self, ms: u64) {
        self.conn_timeout_ms.store(ms, Ordering::Relaxed);
    }

    pub fn conn_timeout(&self) -> Option<Duration> {
        match self.conn_timeout_ms.load(Ordering::Relaxed) {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        }
    }

    fn count_reject(&self, key: &str) {
        if let Ok(mut s) = self.stats.lock() {
            s.rejected += 1;
            // Only attribute to KNOWN tenants — never create a map entry
            // from a client-supplied name, or a scanner cycling random
            // model names grows the stats map (and every stats frame)
            // without bound. Unknown names still count globally above.
            if let Some(c) = s.per_adapter.get_mut(key) {
                c.rejected += 1;
            }
        }
    }
}

// --------------------------------------------------------------------------
// Adapter directories
// --------------------------------------------------------------------------

/// The engine loop's view of the adapter registry: name-keyed lifecycle plus
/// name→slot resolution. Implementations mutate their registry *only* from
/// the engine loop, which is what serializes hot swaps with launches.
pub trait AdapterDirectory {
    fn load(
        &mut self,
        name: &str,
        slot: Option<usize>,
        source: &AdapterSource,
        backend: &mut dyn Backend,
    ) -> Result<AdapterInfo>;

    fn unload(&mut self, name: &str, backend: &mut dyn Backend) -> Result<AdapterInfo>;

    fn list(&self) -> Vec<AdapterInfo>;

    /// `None` name = base model (slot -1). Unknown names return `None`.
    fn resolve(&self, name: Option<&str>) -> Option<i32>;
}

/// Directory over the real [`VirtualizedRegistry`]: loads write a bank slot
/// and sync lazily into the backend; unloads zero the slot and free it for
/// reuse (lowest free slot wins, matching the paper's bounded bank).
pub struct RegistryDirectory {
    pub registry: VirtualizedRegistry,
    manifest: Manifest,
    store: Option<WeightStore>,
}

impl RegistryDirectory {
    pub fn new(registry: VirtualizedRegistry, manifest: Manifest, store: Option<WeightStore>) -> Self {
        Self { registry, manifest, store }
    }

    fn fetch(&self, name: &str, source: &AdapterSource) -> Result<LoraAdapter> {
        match source {
            AdapterSource::StoreIndex(idx) => {
                let store = self
                    .store
                    .as_ref()
                    .ok_or_else(|| anyhow!("no weight store attached (load by path instead)"))?;
                LoraAdapter::from_store(store, &self.manifest, *idx, name)
            }
            AdapterSource::Path(p) => {
                let mut ad = LoraAdapter::load(p)?;
                ad.name = name.to_string();
                Ok(ad)
            }
            AdapterSource::Blank => Ok(LoraAdapter {
                name: name.to_string(),
                rank: self.manifest.build.lora.rank,
                alpha: self.manifest.build.lora.alpha,
                modules: BTreeMap::new(),
            }),
        }
    }
}

impl AdapterDirectory for RegistryDirectory {
    fn load(
        &mut self,
        name: &str,
        slot: Option<usize>,
        source: &AdapterSource,
        backend: &mut dyn Backend,
    ) -> Result<AdapterInfo> {
        if self.registry.model_by_name(name).is_some() {
            return Err(anyhow!("model '{name}' already loaded"));
        }
        let adapter = self.fetch(name, source)?;
        let rank = adapter.rank;
        let slot = match slot {
            Some(s) => {
                self.registry.attach(name, adapter, s, SlotState::Inference)?;
                s
            }
            None => self.registry.attach_auto(name, adapter, SlotState::Inference)?.slot,
        };
        backend.sync_adapters(&mut self.registry)?;
        Ok(AdapterInfo { name: name.to_string(), slot, state: "inference", rank })
    }

    fn unload(&mut self, name: &str, backend: &mut dyn Backend) -> Result<AdapterInfo> {
        let rank = self
            .registry
            .model_by_name(name)
            .map(|vm| vm.rank)
            .ok_or_else(|| anyhow!("model '{name}' not loaded"))?;
        let (slot, _payload) = self.registry.detach_by_name(name)?;
        backend.sync_adapters(&mut self.registry)?;
        Ok(AdapterInfo { name: name.to_string(), slot, state: "free", rank })
    }

    fn list(&self) -> Vec<AdapterInfo> {
        self.registry
            .active_slots()
            .map(|vm| AdapterInfo {
                name: vm.name.clone(),
                slot: vm.slot,
                state: match vm.state {
                    SlotState::Finetune => "finetune",
                    _ => "inference",
                },
                rank: vm.rank,
            })
            .collect()
    }

    fn resolve(&self, name: Option<&str>) -> Option<i32> {
        match name {
            None => Some(-1),
            Some(n) => self.registry.model_by_name(n).map(|vm| vm.slot as i32),
        }
    }
}

/// Directory over a plain name→slot table — for sim-backend deployments and
/// tests, where adapter weights are irrelevant but the lifecycle (slot
/// reuse, name resolution, busy checks) must behave exactly like the real
/// registry.
pub struct StaticDirectory {
    max_slots: usize,
    by_name: BTreeMap<String, usize>,
    rank: usize,
}

impl StaticDirectory {
    pub fn new(max_slots: usize, rank: usize) -> Self {
        Self { max_slots, by_name: BTreeMap::new(), rank }
    }
}

impl AdapterDirectory for StaticDirectory {
    fn load(
        &mut self,
        name: &str,
        slot: Option<usize>,
        _source: &AdapterSource,
        _backend: &mut dyn Backend,
    ) -> Result<AdapterInfo> {
        if self.by_name.contains_key(name) {
            return Err(anyhow!("model '{name}' already loaded"));
        }
        let used: Vec<usize> = self.by_name.values().copied().collect();
        let slot = match slot {
            Some(s) if s < self.max_slots && !used.contains(&s) => s,
            Some(s) => return Err(anyhow!("slot {s} unavailable")),
            None => match (0..self.max_slots).find(|s| !used.contains(s)) {
                Some(s) => s,
                None => return Err(anyhow!("bank full ({} slots)", self.max_slots)),
            },
        };
        self.by_name.insert(name.to_string(), slot);
        Ok(AdapterInfo { name: name.to_string(), slot, state: "inference", rank: self.rank })
    }

    fn unload(&mut self, name: &str, _backend: &mut dyn Backend) -> Result<AdapterInfo> {
        let slot = self
            .by_name
            .remove(name)
            .ok_or_else(|| anyhow!("model '{name}' not loaded"))?;
        Ok(AdapterInfo { name: name.to_string(), slot, state: "free", rank: self.rank })
    }

    fn list(&self) -> Vec<AdapterInfo> {
        let mut v: Vec<AdapterInfo> = self
            .by_name
            .iter()
            .map(|(n, &s)| AdapterInfo {
                name: n.clone(),
                slot: s,
                state: "inference",
                rank: self.rank,
            })
            .collect();
        v.sort_by_key(|a| a.slot);
        v
    }

    fn resolve(&self, name: Option<&str>) -> Option<i32> {
        match name {
            None => Some(-1),
            Some(n) => self.by_name.get(n).map(|&s| s as i32),
        }
    }
}

// --------------------------------------------------------------------------
// Engine loop
// --------------------------------------------------------------------------

struct Pending {
    events: Sender<TokenEvent>,
    key: String,
    start: Stopwatch,
    emitted: usize,
}

/// Consecutive `Coordinator::step` failures tolerated before the engine
/// loop gives up. Each failure already survived the coordinator's own
/// retry/isolate supervision, so reaching this cap means the backend (or
/// the ledger) is persistently broken, not transiently faulty.
const MAX_CONSECUTIVE_STEP_FAILURES: u32 = 8;

/// The serving engine loop: owns the coordinator, backend and directory.
/// Runs until a `shutdown` op drains it or every frontend handle is gone.
///
/// One iteration = drain control/generate messages, run one coordinator
/// step, route tokens/completions back, publish stats. Registry mutations
/// happen strictly between steps — the control channel is what makes
/// adapter hot-swap safe without locks on the launch path.
///
/// The step call is supervised (DESIGN.md §12): a step error does not kill
/// the loop. The coordinator treats the failure as a backend reset — every
/// in-flight stream is preempted (generated tokens fold back into the
/// prompt and recompute, PR 4's recovery path) and the loop continues.
/// Only [`MAX_CONSECUTIVE_STEP_FAILURES`] failures in a row propagate.
pub fn engine_loop(
    coord: &mut Coordinator,
    backend: &mut dyn Backend,
    dir: &mut dyn AdapterDirectory,
    rx: &Receiver<EngineMsg>,
    frontend: &Arc<Frontend>,
) -> Result<()> {
    let t0 = Stopwatch::start();
    let mut waiting: BTreeMap<u64, Pending> = BTreeMap::new();
    let mut draining = false;
    let mut drain_replies: Vec<Sender<()>> = Vec::new();
    let mut consecutive_failures = 0u32;

    if let Ok(mut s) = frontend.stats.lock() {
        s.loaded_adapters = dir.list().len();
    }

    loop {
        // ---- Ingest messages (non-blocking while there is engine work).
        loop {
            match rx.try_recv() {
                Ok(msg) => handle_msg(
                    msg, coord, backend, dir, frontend, &mut waiting, &mut draining,
                    &mut drain_replies, t0,
                ),
                Err(_) => break,
            }
        }

        // ---- Drained? (after shutdown: no queued/active inference left)
        if draining && !coord.has_inference_work() && waiting.is_empty() {
            for r in drain_replies.drain(..) {
                let _ = r.send(());
            }
            publish_stats(coord, &*backend, dir, frontend, t0);
            return Ok(());
        }

        // ---- One step (supervised: a failed step never kills the loop
        // outright — the coordinator already retried and isolated, so an
        // Err here is treated as a backend reset and recovered from).
        coord.advance_clock(t0.elapsed_s());
        let out = match coord.step(backend) {
            Ok(out) => {
                consecutive_failures = 0;
                out
            }
            Err(e) => {
                consecutive_failures += 1;
                eprintln!(
                    "engine: step failed ({consecutive_failures} consecutive): {e:#}"
                );
                if consecutive_failures >= MAX_CONSECUTIVE_STEP_FAILURES {
                    return Err(e.context("engine loop: persistent step failure"));
                }
                let recovered = coord.recover_backend_reset()?;
                eprintln!(
                    "engine: backend reset; {recovered} stream(s) preempted for recompute"
                );
                continue;
            }
        };

        for id in &out.dropped_requests {
            if let Some(p) = waiting.remove(id) {
                let _ = p.events.send(TokenEvent::Error {
                    code: ErrCode::Overloaded,
                    msg: "timed out in queue".to_string(),
                });
            }
        }
        for id in &out.quarantined_requests {
            if let Some(p) = waiting.remove(id) {
                let _ = p.events.send(TokenEvent::Error {
                    code: ErrCode::Quarantined,
                    msg: "request quarantined after repeated step failures".to_string(),
                });
            }
        }
        // Per-step stat deltas, folded into the shared map under ONE lock
        // below — the per-token path must not contend on the stats mutex.
        let mut decoded: BTreeMap<String, u64> = BTreeMap::new();
        let mut completed_keys: Vec<String> = Vec::new();
        let mut dead: Vec<u64> = Vec::new();
        for &(id, tok) in &out.emitted_tokens {
            if let Some(p) = waiting.get_mut(&id) {
                if p.events.send(TokenEvent::Token { index: p.emitted, token: tok }).is_err() {
                    // Client gone (disconnected mid-stream): stop burning
                    // engine capacity on it.
                    dead.push(id);
                    continue;
                }
                p.emitted += 1;
                match decoded.get_mut(&p.key) {
                    Some(n) => *n += 1,
                    None => {
                        decoded.insert(p.key.clone(), 1);
                    }
                }
            }
        }
        for id in dead {
            waiting.remove(&id);
            let _ = coord.cancel(id);
        }
        for (id, tokens) in out.completed_outputs {
            if let Some(p) = waiting.remove(&id) {
                let latency_s = p.start.elapsed_s();
                completed_keys.push(p.key.clone());
                let _ = p.events.send(TokenEvent::Done { tokens, latency_s });
            }
        }
        if !decoded.is_empty() || !completed_keys.is_empty() {
            if let Ok(mut s) = frontend.stats.lock() {
                for (key, n) in decoded {
                    s.per_adapter.entry(key).or_default().decode_tokens += n;
                }
                for key in completed_keys {
                    s.per_adapter.entry(key).or_default().completed += 1;
                }
            }
        }

        publish_stats(coord, &*backend, dir, frontend, t0);

        // ---- Idle: block briefly on the channel instead of spinning.
        if out.idle {
            match rx.recv_timeout(Duration::from_millis(2)) {
                Ok(msg) => handle_msg(
                    msg, coord, backend, dir, frontend, &mut waiting, &mut draining,
                    &mut drain_replies, t0,
                ),
                Err(RecvTimeoutError::Timeout) => {}
                // All frontend handles dropped: nothing can ever arrive.
                Err(RecvTimeoutError::Disconnected) => return Ok(()),
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_msg(
    msg: EngineMsg,
    coord: &mut Coordinator,
    backend: &mut dyn Backend,
    dir: &mut dyn AdapterDirectory,
    frontend: &Arc<Frontend>,
    waiting: &mut BTreeMap<u64, Pending>,
    draining: &mut bool,
    drain_replies: &mut Vec<Sender<()>>,
    t0: Stopwatch,
) {
    match msg {
        EngineMsg::Generate(job) => {
            if *draining {
                let _ = job.events.send(TokenEvent::Error {
                    code: ErrCode::Overloaded,
                    msg: "draining".to_string(),
                });
                return;
            }
            let key = job.model.clone().unwrap_or_default();
            let Some(adapter) = dir.resolve(job.model.as_deref()) else {
                frontend.count_reject(&key);
                let _ = job.events.send(TokenEvent::Error {
                    code: ErrCode::BadRequest,
                    msg: format!("unknown model '{key}'"),
                });
                return;
            };
            if job.prompt.is_empty() {
                frontend.count_reject(&key);
                let _ = job.events.send(TokenEvent::Error {
                    code: ErrCode::BadRequest,
                    msg: "empty prompt".to_string(),
                });
                return;
            }
            // A request whose worst-case reservation can never fit would
            // head-of-line-block the queue forever — reject it up front.
            if !coord.request_fits(job.prompt.len(), job.max_new_tokens) {
                frontend.count_reject(&key);
                let _ = job.events.send(TokenEvent::Error {
                    code: ErrCode::BadRequest,
                    msg: format!(
                        "request exceeds capacity (max_new_tokens {} too large for this deployment)",
                        job.max_new_tokens
                    ),
                });
                return;
            }
            let now = t0.elapsed_s();
            coord.advance_clock(now);
            if let Ok(mut s) = frontend.stats.lock() {
                s.per_adapter.entry(key.clone()).or_default().submitted += 1;
            }
            waiting.insert(
                job.id,
                Pending { events: job.events, key, start: Stopwatch::start(), emitted: 0 },
            );
            coord.submit(InferenceRequest {
                id: job.id,
                adapter,
                prompt: job.prompt,
                max_new_tokens: job.max_new_tokens,
                eos_token: None,
                arrival_s: now,
                // Deadlines attach at submit time: wire-level `slo_*`
                // overrides resolve against the deployment's configured
                // spec; None (no overrides) inherits it wholesale.
                slo: job.slo.resolve(&coord.cfg.slo),
            });
        }
        EngineMsg::Control(c) => {
            let reply = match c.op {
                ControlOp::Load { name, slot, source } => {
                    match dir.load(&name, slot, &source, backend) {
                        Ok(info) => ControlReply::Loaded { name: info.name, slot: info.slot },
                        Err(e) => ControlReply::Err(e.to_string()),
                    }
                }
                ControlOp::Unload { name } => {
                    // Refuse while work references the slot: zeroing a bank
                    // block mid-generation would corrupt those requests.
                    match dir.resolve(Some(&name)) {
                        Some(slot) if coord.adapter_in_use(slot) => {
                            ControlReply::Err(format!("model '{name}' busy (requests in flight)"))
                        }
                        _ => match dir.unload(&name, backend) {
                            Ok(info) => {
                                ControlReply::Unloaded { name: info.name, slot: info.slot }
                            }
                            Err(e) => ControlReply::Err(e.to_string()),
                        },
                    }
                }
                ControlOp::List => ControlReply::Adapters(dir.list()),
            };
            if let Ok(mut s) = frontend.stats.lock() {
                s.loaded_adapters = dir.list().len();
            }
            let _ = c.reply.send(reply);
        }
        EngineMsg::Shutdown { reply } => {
            *draining = true;
            frontend.set_draining();
            drain_replies.push(reply);
        }
    }
}

fn publish_stats(
    coord: &Coordinator,
    backend: &dyn Backend,
    dir: &dyn AdapterDirectory,
    frontend: &Arc<Frontend>,
    t0: Stopwatch,
) {
    if let Ok(mut s) = frontend.stats.lock() {
        s.queued = coord.queue_len();
        s.active = coord.active_len();
        s.completed = coord.traces.len();
        s.decode_tokens = coord.decode_series.total() as u64;
        s.finetune_tokens = coord.finetune_tokens();
        s.loaded_adapters = dir.list().len();
        s.preemptions = coord.preempted_total();
        let kv = coord.kv.stats();
        s.kv_blocks_used = kv.blocks_used;
        s.kv_blocks_total = kv.blocks_total;
        s.kv_frag_tokens = kv.tokens_reserved_unused;
        s.kv_frag_peak_tokens = coord.kv_frag_peak_tokens();
        s.prefix_hits = coord.prefix_hits();
        s.prefill_tokens_saved = coord.prefill_tokens_saved();
        s.kv_blocks_shared = kv.kv_blocks_shared;
        s.adapter_swaps = coord.adapter_swaps();
        s.adapter_resident = coord.adapter_resident();
        s.adapter_host = coord.adapter_host();
        s.faults_injected = backend.faults_injected();
        s.step_retries = coord.step_retries_total();
        s.quarantined = coord.quarantined_total();
        s.checkpoints_written = coord.checkpoints_written();
        s.backend_resets = coord.backend_resets();
        // Live SLO view (DESIGN.md §9): attainment plus per-adapter
        // TTFT/TPOT quantiles, resolved from bank slots back to model
        // names (slot -1 = the base model = the "" key).
        let tracker = coord.slo_live();
        s.slo_attainment = tracker.attainment();
        s.per_adapter_latency.clear();
        let loaded = dir.list();
        for slot in tracker.adapters() {
            let name = if slot < 0 {
                Some(String::new())
            } else {
                loaded.iter().find(|a| a.slot as i32 == slot).map(|a| a.name.clone())
            };
            if let (Some(name), Some(summary)) = (name, tracker.summary(slot)) {
                s.per_adapter_latency.insert(name, summary);
            }
        }
        let depth = (coord.queue_len() + coord.preempted_len() + coord.active_len()) as f64;
        s.queue_depth.sample(t0.elapsed_s(), depth);
    }
}

// --------------------------------------------------------------------------
// Connection handling
// --------------------------------------------------------------------------

fn err_frame(id: Option<u64>, code: ErrCode, msg: &str) -> String {
    err_frame_with(id, code, msg, None)
}

/// Error frame: `{"id":..,"error":msg,"err":name,"code":n[,"retry_after_ms":..]}`.
/// The numeric `code` key predates `err` and stays for older clients.
fn err_frame_with(
    id: Option<u64>,
    code: ErrCode,
    msg: &str,
    retry_after_ms: Option<u64>,
) -> String {
    let mut kvs = Vec::new();
    if let Some(id) = id {
        kvs.push(("id", Json::Num(id as f64)));
    }
    kvs.push(("error", Json::Str(msg.to_string())));
    kvs.push(("err", Json::Str(code.name().to_string())));
    kvs.push(("code", Json::Num(code.code() as f64)));
    if let Some(ms) = retry_after_ms {
        kvs.push(("retry_after_ms", Json::Num(ms as f64)));
    }
    Json::obj(kvs).to_string()
}

/// Deterministic backoff hint on 503 admission rejects: scales with the
/// instantaneous in-flight count and caps at 5s, so a synchronized retry
/// herd staggers itself by observed queue depth without any randomness.
fn retry_after_ms(inflight: usize) -> u64 {
    (100 * (1 + inflight as u64)).min(5_000)
}

fn write_line(w: &mut TcpStream, line: &str) -> bool {
    w.write_all(line.as_bytes()).is_ok() && w.write_all(b"\n").is_ok()
}

/// Handle one connection (blocking; one thread per connection).
fn handle_conn(
    stream: TcpStream,
    fe: Arc<Frontend>,
    encode: Arc<dyn Fn(&str) -> Vec<i32> + Send + Sync>,
    decode: Arc<dyn Fn(&[i32]) -> String + Send + Sync>,
) {
    // Half-open clients (dead without FIN, or never draining their socket)
    // must not pin this thread forever: both directions time out, and the
    // resulting read/write error closes the connection server-side.
    let _ = stream.set_read_timeout(fe.conn_timeout());
    let _ = stream.set_write_timeout(fe.conn_timeout());
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let msg = match ClientMsg::parse(&line) {
            Ok(m) => m,
            Err(e) => {
                let frame = err_frame(None, ErrCode::BadRequest, &format!("bad request: {e}"));
                if !write_line(&mut writer, &frame) {
                    break;
                }
                continue;
            }
        };
        let keep_going = match msg {
            ClientMsg::Generate { prompt, model, max_new_tokens, stream, slo } => handle_generate(
                &mut writer, &fe, &encode, &decode, prompt, model, max_new_tokens, stream, slo,
            ),
            ClientMsg::LoadAdapter { name, slot, source } => {
                handle_control(&mut writer, &fe, ControlOp::Load { name, slot, source })
            }
            ClientMsg::UnloadAdapter { name } => {
                handle_control(&mut writer, &fe, ControlOp::Unload { name })
            }
            ClientMsg::ListAdapters => handle_control(&mut writer, &fe, ControlOp::List),
            ClientMsg::Stats => {
                // Serialize under the lock (to_json only reads) instead of
                // deep-cloning the gauge series per poll.
                let frame = match fe.stats.lock() {
                    Ok(s) => s.to_json().to_string(),
                    Err(_) => err_frame(None, ErrCode::Internal, "stats unavailable"),
                };
                write_line(&mut writer, &frame)
            }
            ClientMsg::Shutdown => {
                let (tx, rx) = channel();
                fe.set_draining();
                if fe.send(EngineMsg::Shutdown { reply: tx }).is_err() {
                    write_line(&mut writer, &err_frame(None, ErrCode::Internal, "engine loop gone"))
                } else {
                    // Block until the engine has drained in-flight work. A
                    // dropped reply means the engine died WITHOUT draining —
                    // never ack that as a clean drain.
                    let frame = match rx.recv() {
                        Ok(()) => Json::obj(vec![
                            ("ok", Json::Bool(true)),
                            ("drained", Json::Bool(true)),
                        ])
                        .to_string(),
                        Err(_) => err_frame(None, ErrCode::Internal, "engine exited without draining"),
                    };
                    write_line(&mut writer, &frame)
                }
            }
        };
        if !keep_going {
            break;
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_generate(
    writer: &mut TcpStream,
    fe: &Arc<Frontend>,
    encode: &Arc<dyn Fn(&str) -> Vec<i32> + Send + Sync>,
    decode: &Arc<dyn Fn(&[i32]) -> String + Send + Sync>,
    prompt: String,
    model: Option<String>,
    max_new_tokens: usize,
    stream: bool,
    slo: SloOverride,
) -> bool {
    let key = model.clone().unwrap_or_default();
    // Admission control: bounded queue + per-adapter fair share. A refusal
    // is a 503-style frame, not a silent queue without bound.
    let _guard = match fe.try_admit(&key) {
        Ok(g) => g,
        Err(reason) => {
            fe.count_reject(&key);
            // 503 rejects tell the client when to come back: a hint that
            // scales with the load that caused the reject.
            let hint = retry_after_ms(fe.inflight());
            return write_line(
                writer,
                &err_frame_with(None, ErrCode::Overloaded, &reason, Some(hint)),
            );
        }
    };
    let id = fe.next_id();
    let (events_tx, events_rx) = channel();
    let job = GenerateJob {
        id,
        model,
        prompt: encode(&prompt),
        max_new_tokens,
        slo,
        events: events_tx,
    };
    if fe.send(EngineMsg::Generate(job)).is_err() {
        return write_line(writer, &err_frame(Some(id), ErrCode::Internal, "engine loop gone"));
    }
    loop {
        match events_rx.recv() {
            Ok(TokenEvent::Token { index, token }) => {
                if stream {
                    let frame = Json::obj(vec![
                        ("id", Json::Num(id as f64)),
                        ("index", Json::Num(index as f64)),
                        ("token", Json::Num(token as f64)),
                        ("text", Json::Str(decode(&[token]))),
                    ]);
                    if !write_line(writer, &frame.to_string()) {
                        // Client hung up mid-stream: stop forwarding; the
                        // guard still releases admission on return.
                        return false;
                    }
                }
            }
            Ok(TokenEvent::Done { tokens, latency_s }) => {
                let mut kvs = vec![("id", Json::Num(id as f64))];
                if stream {
                    kvs.push(("done", Json::Bool(true)));
                }
                kvs.push(("text", Json::Str(decode(&tokens))));
                kvs.push((
                    "tokens",
                    Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
                ));
                kvs.push(("latency_s", Json::Num(latency_s)));
                return write_line(writer, &Json::obj(kvs).to_string());
            }
            Ok(TokenEvent::Error { code, msg }) => {
                return write_line(writer, &err_frame(Some(id), code, &msg));
            }
            Err(_) => {
                return write_line(
                    writer,
                    &err_frame(Some(id), ErrCode::Internal, "engine dropped request"),
                );
            }
        }
    }
}

fn handle_control(writer: &mut TcpStream, fe: &Arc<Frontend>, op: ControlOp) -> bool {
    let (tx, rx) = channel();
    if fe.send(EngineMsg::Control(ControlMsg { op, reply: tx })).is_err() {
        return write_line(writer, &err_frame(None, ErrCode::Internal, "engine loop gone"));
    }
    let frame = match rx.recv() {
        Ok(ControlReply::Loaded { name, slot }) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("name", Json::Str(name)),
            ("slot", Json::Num(slot as f64)),
        ])
        .to_string(),
        Ok(ControlReply::Unloaded { name, slot }) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("name", Json::Str(name)),
            ("slot", Json::Num(slot as f64)),
        ])
        .to_string(),
        Ok(ControlReply::Adapters(list)) => Json::obj(vec![(
            "adapters",
            Json::Arr(list.iter().map(|a| a.to_json()).collect()),
        )])
        .to_string(),
        Ok(ControlReply::Err(e)) => err_frame(None, ErrCode::Conflict, &e),
        Err(_) => err_frame(None, ErrCode::Internal, "engine dropped control op"),
    };
    write_line(writer, &frame)
}

/// Accept loop: spawns a thread per connection. Blocks until the listener
/// errors (or the process exits with the engine loop).
pub fn serve_blocking(
    listener: TcpListener,
    frontend: Arc<Frontend>,
    encode: impl Fn(&str) -> Vec<i32> + Send + Sync + 'static,
    decode: impl Fn(&[i32]) -> String + Send + Sync + 'static,
) -> Result<()> {
    let encode: Arc<dyn Fn(&str) -> Vec<i32> + Send + Sync> = Arc::new(encode);
    let decode: Arc<dyn Fn(&[i32]) -> String + Send + Sync> = Arc::new(decode);
    for stream in listener.incoming() {
        let stream = stream?;
        let (fe, e, d) = (frontend.clone(), encode.clone(), decode.clone());
        // lint:allow(thread-spawn) I/O concurrency, not compute: one blocking reader per socket never touches kernel math, so lane count cannot reach output bits (§7 governs the worker pool only)
        std::thread::spawn(move || handle_conn(stream, fe, e, d));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_msg_parses_generate() {
        let m = ClientMsg::parse(r#"{"op":"generate","prompt":"hi","max_new_tokens":4}"#).unwrap();
        match m {
            ClientMsg::Generate { max_new_tokens, stream, .. } => {
                assert_eq!(max_new_tokens, 4);
                assert!(!stream);
            }
            _ => panic!(),
        }
        let s = ClientMsg::parse(r#"{"op":"stats"}"#).unwrap();
        assert!(matches!(s, ClientMsg::Stats));
    }

    #[test]
    fn generate_defaults_and_stream_flag() {
        let m = ClientMsg::parse(r#"{"op":"generate","prompt":"hi","stream":true}"#).unwrap();
        match m {
            ClientMsg::Generate { max_new_tokens, model, stream, slo, .. } => {
                assert_eq!(max_new_tokens, 32);
                assert!(model.is_none());
                assert!(stream);
                assert!(!slo.is_set(), "no slo_* keys = inherit the deployment spec");
            }
            _ => panic!(),
        }
    }

    #[test]
    fn generate_parses_per_request_slo_overrides() {
        let m = ClientMsg::parse(
            r#"{"op":"generate","prompt":"hi","slo_max_waiting_s":2.5,"slo_max_decode_s":0.5}"#,
        )
        .unwrap();
        let ClientMsg::Generate { slo, .. } = m else { panic!() };
        assert_eq!(slo.max_waiting_s, Some(2.5));
        assert_eq!(slo.mean_decode_s, None);
        assert_eq!(slo.max_decode_s, Some(0.5));
        // Partial overrides resolve against the deployment default.
        let d = crate::metrics::SloSpec::default();
        let spec = slo.resolve(&d).unwrap();
        assert_eq!(spec.max_waiting_s, 2.5);
        assert_eq!(spec.mean_decode_latency_s, d.mean_decode_latency_s);
        assert_eq!(spec.max_decode_latency_s, 0.5);
        // Negative bounds are ignored, not honored.
        let m = ClientMsg::parse(
            r#"{"op":"generate","prompt":"hi","slo_max_waiting_s":-1}"#,
        )
        .unwrap();
        let ClientMsg::Generate { slo, .. } = m else { panic!() };
        assert!(!slo.is_set());
        assert!(slo.resolve(&d).is_none());
    }

    #[test]
    fn generate_clamps_max_new_tokens() {
        let m =
            ClientMsg::parse(r#"{"op":"generate","prompt":"x","max_new_tokens":999999}"#).unwrap();
        match m {
            ClientMsg::Generate { max_new_tokens, .. } => {
                assert_eq!(max_new_tokens, MAX_NEW_TOKENS_CAP)
            }
            _ => panic!(),
        }
    }

    #[test]
    fn lifecycle_ops_parse() {
        let m = ClientMsg::parse(r#"{"op":"load_adapter","name":"vm9","index":2}"#).unwrap();
        match m {
            ClientMsg::LoadAdapter { name, slot, source } => {
                assert_eq!(name, "vm9");
                assert!(slot.is_none());
                assert_eq!(source, AdapterSource::StoreIndex(2));
            }
            _ => panic!(),
        }
        let m =
            ClientMsg::parse(r#"{"op":"load_adapter","name":"a","slot":3,"path":"x.json"}"#)
                .unwrap();
        match m {
            ClientMsg::LoadAdapter { slot, source, .. } => {
                assert_eq!(slot, Some(3));
                assert_eq!(source, AdapterSource::Path("x.json".into()));
            }
            _ => panic!(),
        }
        let m = ClientMsg::parse(r#"{"op":"load_adapter","name":"b"}"#).unwrap();
        match m {
            ClientMsg::LoadAdapter { source, .. } => assert_eq!(source, AdapterSource::Blank),
            _ => panic!(),
        }
        let m = ClientMsg::parse(r#"{"op":"unload_adapter","name":"vm9"}"#).unwrap();
        assert!(matches!(m, ClientMsg::UnloadAdapter { .. }));
        assert!(matches!(
            ClientMsg::parse(r#"{"op":"list_adapters"}"#).unwrap(),
            ClientMsg::ListAdapters
        ));
        assert!(matches!(
            ClientMsg::parse(r#"{"op":"shutdown"}"#).unwrap(),
            ClientMsg::Shutdown
        ));
    }

    #[test]
    fn bad_msgs_are_errors_not_panics() {
        assert!(ClientMsg::parse(r#"{"op":"nope"}"#).is_err());
        assert!(ClientMsg::parse("not json").is_err());
        assert!(ClientMsg::parse(r#"{"op":"generate"}"#).is_err(), "prompt required");
        assert!(ClientMsg::parse(r#"{"op":"load_adapter"}"#).is_err(), "name required");
        assert!(ClientMsg::parse(r#"{"op":"unload_adapter"}"#).is_err());
        assert!(
            ClientMsg::parse(r#"{"op":"load_adapter","name":"x","slot":-1}"#).is_err(),
            "negative slot rejected"
        );
    }

    #[test]
    fn stats_serialize_with_per_adapter() {
        let mut s = Stats {
            queued: 1,
            active: 2,
            completed: 3,
            decode_tokens: 4,
            finetune_tokens: 5,
            rejected: 6,
            loaded_adapters: 2,
            preemptions: 7,
            kv_blocks_used: 11,
            kv_blocks_total: 24,
            kv_frag_tokens: 13,
            kv_frag_peak_tokens: 99,
            prefix_hits: 31,
            prefill_tokens_saved: 496,
            kv_blocks_shared: 12,
            adapter_swaps: 21,
            adapter_resident: 4,
            adapter_host: 17,
            slo_attainment: 0.75,
            faults_injected: 23,
            step_retries: 5,
            quarantined: 1,
            checkpoints_written: 2,
            backend_resets: 1,
            ..Default::default()
        };
        s.per_adapter.insert(
            "vm0".into(),
            AdapterCounters { submitted: 9, completed: 8, rejected: 1, decode_tokens: 70 },
        );
        s.per_adapter_latency.insert(
            "vm0".into(),
            LatencySummary {
                ttft_p50_s: 0.5,
                ttft_p99_s: 2.0,
                tpot_p50_s: 0.02,
                tpot_p99_s: 0.25,
            },
        );
        // A model with latency samples but no counters yet still appears.
        s.per_adapter_latency.insert("vm1".into(), LatencySummary::default());
        s.queue_depth.sample(0.5, 3.0);
        let j = s.to_json().to_string();
        assert!(j.contains("\"queued\":1") && j.contains("\"finetune_tokens\":5"), "{j}");
        assert!(j.contains("\"rejected\":6"), "{j}");
        assert!(j.contains("\"preemptions\":7"), "{j}");
        assert!(
            j.contains("\"kv_blocks_used\":11")
                && j.contains("\"kv_blocks_total\":24")
                && j.contains("\"kv_frag_tokens\":13")
                && j.contains("\"kv_frag_peak_tokens\":99"),
            "{j}"
        );
        assert!(
            j.contains("\"adapter_swaps\":21")
                && j.contains("\"adapter_resident\":4")
                && j.contains("\"adapter_host\":17"),
            "unified-paging counters serialize: {j}"
        );
        assert!(
            j.contains("\"prefix_hits\":31")
                && j.contains("\"prefill_tokens_saved\":496")
                && j.contains("\"kv_blocks_shared\":12"),
            "prefix-sharing counters serialize: {j}"
        );
        assert!(j.contains("\"slo_attainment\":0.75"), "{j}");
        assert!(
            j.contains("\"faults_injected\":23")
                && j.contains("\"step_retries\":5")
                && j.contains("\"quarantined\":1")
                && j.contains("\"checkpoints_written\":2")
                && j.contains("\"backend_resets\":1"),
            "fault-supervision counters serialize: {j}"
        );
        assert!(j.contains("\"vm0\":{\"submitted\":9"), "{j}");
        assert!(
            j.contains("\"ttft_p50_s\":0.5") && j.contains("\"tpot_p99_s\":0.25"),
            "per-adapter latency quantiles serialize: {j}"
        );
        assert!(j.contains("\"vm1\":{\"submitted\":0"), "latency-only model appears: {j}");
        assert!(j.contains("\"queue_depth\":3"), "{j}");
        // And it parses back as JSON.
        assert!(json::parse(&j).is_ok());
    }

    #[test]
    fn err_frames_carry_typed_codes() {
        let f = err_frame(Some(7), ErrCode::Quarantined, "boom");
        let v = json::parse(&f).unwrap();
        assert_eq!(v.req("id").unwrap().as_usize().unwrap(), 7);
        assert_eq!(v.req("error").unwrap().as_str().unwrap(), "boom");
        assert_eq!(v.req("err").unwrap().as_str().unwrap(), "quarantined");
        assert_eq!(v.req("code").unwrap().as_usize().unwrap(), 422);
        // The name↔code table is total and bijective.
        for c in [
            ErrCode::BadRequest,
            ErrCode::Conflict,
            ErrCode::Quarantined,
            ErrCode::Internal,
            ErrCode::Overloaded,
        ] {
            assert!(!c.name().is_empty());
            assert!(c.code() >= 400 && c.code() < 600);
        }
    }

    #[test]
    fn reject_frame_carries_retry_after_hint() {
        let f = err_frame_with(None, ErrCode::Overloaded, "overloaded", Some(retry_after_ms(3)));
        let v = json::parse(&f).unwrap();
        assert_eq!(v.req("code").unwrap().as_usize().unwrap(), 503);
        assert_eq!(v.req("err").unwrap().as_str().unwrap(), "overloaded");
        assert_eq!(v.req("retry_after_ms").unwrap().as_usize().unwrap(), 400);
        assert!(v.get("id").is_none());
        // The hint is deterministic in load and capped.
        assert_eq!(retry_after_ms(0), 100);
        assert_eq!(retry_after_ms(1_000_000), 5_000);
    }

    #[test]
    fn conn_timeout_is_configurable_and_defaults_on() {
        let (fe, _rx) = Frontend::new(AdmissionConfig::default());
        assert_eq!(fe.conn_timeout(), Some(Duration::from_millis(DEFAULT_CONN_TIMEOUT_MS)));
        fe.set_conn_timeout_ms(250);
        assert_eq!(fe.conn_timeout(), Some(Duration::from_millis(250)));
        fe.set_conn_timeout_ms(0);
        assert_eq!(fe.conn_timeout(), None, "0 disables the timeout");
    }

    #[test]
    fn admission_bounds_global_and_per_adapter() {
        let (fe, _rx) = Frontend::new(AdmissionConfig { max_inflight: 3, max_inflight_per_adapter: 2 });
        let g1 = fe.try_admit("a").unwrap();
        let _g2 = fe.try_admit("a").unwrap();
        assert_eq!(fe.try_admit("a").unwrap_err(), "model 'a' over fair-share limit");
        let _g3 = fe.try_admit("b").unwrap();
        assert_eq!(fe.try_admit("c").unwrap_err(), "overloaded");
        assert_eq!(fe.inflight(), 3);
        drop(g1);
        assert_eq!(fe.inflight(), 2);
        // Released capacity is admissible again, for any adapter.
        let _g4 = fe.try_admit("c").unwrap();
    }

    #[test]
    fn draining_refuses_admission() {
        let (fe, _rx) = Frontend::new(AdmissionConfig::default());
        assert!(fe.try_admit("a").is_ok());
        fe.set_draining();
        assert_eq!(fe.try_admit("a").unwrap_err(), "draining");
    }

    #[test]
    fn static_directory_reuses_lowest_free_slot() {
        use crate::engine::{CostModel, SimBackend};
        use crate::harness::{sim_buckets, sim_geometry};
        let mut be = SimBackend::new(sim_geometry(), sim_buckets(), CostModel::default());
        let mut d = StaticDirectory::new(2, 8);
        let a = d.load("a", None, &AdapterSource::Blank, &mut be).unwrap();
        let b = d.load("b", None, &AdapterSource::Blank, &mut be).unwrap();
        assert_eq!((a.slot, b.slot), (0, 1));
        assert!(d.load("c", None, &AdapterSource::Blank, &mut be).is_err(), "bank full");
        assert_eq!(d.unload("a", &mut be).unwrap().slot, 0);
        // Slot 0 is recycled for the next load.
        assert_eq!(d.load("c", None, &AdapterSource::Blank, &mut be).unwrap().slot, 0);
        assert_eq!(d.resolve(Some("c")), Some(0));
        assert_eq!(d.resolve(None), Some(-1));
        assert_eq!(d.resolve(Some("zz")), None);
        assert!(d.load("c", None, &AdapterSource::Blank, &mut be).is_err(), "duplicate name");
        assert_eq!(d.list().len(), 2);
    }
}
