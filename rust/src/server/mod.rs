//! JSON-lines TCP frontend: submit inference requests to a live coordinator
//! and receive completions. Thread-per-connection over std::net (the
//! offline environment has no tokio; the engine loop is single-threaded
//! over the backend anyway, so async buys nothing here).
//!
//! Wire protocol (one JSON object per line):
//!   -> {"op":"generate","prompt":"...","model":"vm0","max_new_tokens":32}
//!   <- {"id":7,"text":"...","tokens":[...],"latency_s":0.42}
//!   -> {"op":"stats"}
//!   <- {"queued":0,"active":1,...}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::coordinator::InferenceRequest;
use crate::util::json::{self, Json};

/// A parsed client message.
#[derive(Debug)]
pub enum ClientMsg {
    Generate { prompt: String, model: Option<String>, max_new_tokens: usize },
    Stats,
}

impl ClientMsg {
    pub fn parse(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        match v.req("op")?.as_str()? {
            "generate" => Ok(ClientMsg::Generate {
                prompt: v.req("prompt")?.as_str()?.to_string(),
                model: v.get("model").and_then(|m| m.as_str().ok()).map(String::from),
                max_new_tokens: v
                    .get("max_new_tokens")
                    .and_then(|n| n.as_usize().ok())
                    .unwrap_or(32),
            }),
            "stats" => Ok(ClientMsg::Stats),
            other => anyhow::bail!("unknown op '{other}'"),
        }
    }
}

/// Serving statistics exposed over the wire.
#[derive(Debug, Default, Clone)]
pub struct Stats {
    pub queued: usize,
    pub active: usize,
    pub completed: usize,
    pub decode_tokens: u64,
    pub finetune_tokens: u64,
}

impl Stats {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("queued", Json::Num(self.queued as f64)),
            ("active", Json::Num(self.active as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("decode_tokens", Json::Num(self.decode_tokens as f64)),
            ("finetune_tokens", Json::Num(self.finetune_tokens as f64)),
        ])
    }
}

/// A request handed from the frontend to the engine loop, with the channel
/// its completion is delivered on.
pub struct FrontendJob {
    pub request: InferenceRequest,
    pub reply: Sender<(Vec<i32>, f64)>,
}

/// Shared state between connection threads and the engine loop.
pub struct Frontend {
    pub jobs_tx: Sender<FrontendJob>,
    pub stats: Arc<Mutex<Stats>>,
    next_id: AtomicU64,
}

impl Frontend {
    pub fn new() -> (Arc<Self>, Receiver<FrontendJob>) {
        let (tx, rx) = channel();
        (
            Arc::new(Self {
                jobs_tx: tx,
                stats: Arc::new(Mutex::new(Stats::default())),
                next_id: AtomicU64::new(1),
            }),
            rx,
        )
    }

    pub fn next_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }
}

/// Handle one connection (blocking; one thread per connection).
fn handle_conn(
    stream: TcpStream,
    fe: Arc<Frontend>,
    encode: Arc<dyn Fn(&str) -> Vec<i32> + Send + Sync>,
    decode: Arc<dyn Fn(&[i32]) -> String + Send + Sync>,
    resolve: Arc<dyn Fn(Option<&str>) -> i32 + Send + Sync>,
) {
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply = match ClientMsg::parse(&line) {
            Ok(ClientMsg::Generate { prompt, model, max_new_tokens }) => {
                let id = fe.next_id();
                let tokens = encode(&prompt);
                let adapter = resolve(model.as_deref());
                let (tx, rx) = channel();
                let job = FrontendJob {
                    request: InferenceRequest {
                        id,
                        adapter,
                        prompt: tokens,
                        max_new_tokens,
                        eos_token: None,
                        arrival_s: 0.0, // stamped by the engine loop
                    },
                    reply: tx,
                };
                if fe.jobs_tx.send(job).is_err() {
                    break;
                }
                match rx.recv() {
                    Ok((out_tokens, latency_s)) => Json::obj(vec![
                        ("id", Json::Num(id as f64)),
                        ("text", Json::Str(decode(&out_tokens))),
                        (
                            "tokens",
                            Json::Arr(out_tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
                        ),
                        ("latency_s", Json::Num(latency_s)),
                    ])
                    .to_string(),
                    Err(_) => r#"{"error":"engine dropped request"}"#.to_string(),
                }
            }
            Ok(ClientMsg::Stats) => {
                let s = fe.stats.lock().map(|s| s.clone()).unwrap_or_default();
                s.to_json().to_string()
            }
            Err(e) => Json::obj(vec![("error", Json::Str(format!("bad request: {e}")))]).to_string(),
        };
        if writer.write_all(reply.as_bytes()).is_err() || writer.write_all(b"\n").is_err() {
            break;
        }
    }
}

/// Accept loop: spawns a thread per connection. Blocks forever.
pub fn serve_blocking(
    listener: TcpListener,
    frontend: Arc<Frontend>,
    encode: impl Fn(&str) -> Vec<i32> + Send + Sync + 'static,
    decode: impl Fn(&[i32]) -> String + Send + Sync + 'static,
    resolve_model: impl Fn(Option<&str>) -> i32 + Send + Sync + 'static,
) -> Result<()> {
    let encode: Arc<dyn Fn(&str) -> Vec<i32> + Send + Sync> = Arc::new(encode);
    let decode: Arc<dyn Fn(&[i32]) -> String + Send + Sync> = Arc::new(decode);
    let resolve: Arc<dyn Fn(Option<&str>) -> i32 + Send + Sync> = Arc::new(resolve_model);
    for stream in listener.incoming() {
        let stream = stream?;
        let (fe, e, d, r) = (frontend.clone(), encode.clone(), decode.clone(), resolve.clone());
        std::thread::spawn(move || handle_conn(stream, fe, e, d, r));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_msg_parses() {
        let m = ClientMsg::parse(r#"{"op":"generate","prompt":"hi","max_new_tokens":4}"#).unwrap();
        assert!(matches!(m, ClientMsg::Generate { max_new_tokens: 4, .. }));
        let s = ClientMsg::parse(r#"{"op":"stats"}"#).unwrap();
        assert!(matches!(s, ClientMsg::Stats));
    }

    #[test]
    fn defaults_applied() {
        let m = ClientMsg::parse(r#"{"op":"generate","prompt":"hi"}"#).unwrap();
        match m {
            ClientMsg::Generate { max_new_tokens, model, .. } => {
                assert_eq!(max_new_tokens, 32);
                assert!(model.is_none());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn bad_msg_is_error_not_panic() {
        assert!(ClientMsg::parse(r#"{"op":"nope"}"#).is_err());
        assert!(ClientMsg::parse("not json").is_err());
    }

    #[test]
    fn stats_serialize() {
        let s = Stats { queued: 1, active: 2, completed: 3, decode_tokens: 4, finetune_tokens: 5 };
        let j = s.to_json().to_string();
        assert!(j.contains("\"queued\":1") && j.contains("\"finetune_tokens\":5"));
    }
}
