//! Byte-level tokenizer with a trained merge table — the real-text path for
//! examples (the AOT model's 512-token vocabulary = 256 byte tokens + 255
//! learned merges + EOS).

use std::collections::HashMap;

use anyhow::Result;

/// Byte-pair tokenizer over a fixed vocabulary.
pub struct Tokenizer {
    /// merge rank: (left, right) -> merged token id.
    merges: HashMap<(i32, i32), i32>,
    /// token id -> byte string.
    vocab: Vec<Vec<u8>>,
    pub eos: i32,
}

impl Tokenizer {
    /// Train merges greedily on a corpus until `vocab_size` is reached.
    /// (Deterministic: ties break on the lexicographically first pair.)
    pub fn train(corpus: &str, vocab_size: usize) -> Self {
        let mut vocab: Vec<Vec<u8>> = (0..=255u8).map(|b| vec![b]).collect();
        let mut merges = HashMap::new();
        let mut seqs: Vec<Vec<i32>> = corpus
            .split_whitespace()
            .map(|w| w.bytes().map(|b| b as i32).collect())
            .collect();

        while vocab.len() + 1 < vocab_size {
            // Count adjacent pairs.
            let mut counts: HashMap<(i32, i32), usize> = HashMap::new();
            for s in &seqs {
                for w in s.windows(2) {
                    *counts.entry((w[0], w[1])).or_default() += 1;
                }
            }
            let Some((&pair, &n)) = counts
                .iter()
                .max_by_key(|(p, n)| (**n, std::cmp::Reverse(**p)))
            else {
                break;
            };
            if n < 2 {
                break;
            }
            let new_id = vocab.len() as i32;
            let mut merged = vocab[pair.0 as usize].clone();
            merged.extend_from_slice(&vocab[pair.1 as usize]);
            vocab.push(merged);
            merges.insert(pair, new_id);
            // Apply the merge everywhere.
            for s in seqs.iter_mut() {
                let mut out = Vec::with_capacity(s.len());
                let mut i = 0;
                while i < s.len() {
                    if i + 1 < s.len() && (s[i], s[i + 1]) == pair {
                        out.push(new_id);
                        i += 2;
                    } else {
                        out.push(s[i]);
                        i += 1;
                    }
                }
                *s = out;
            }
        }
        let eos = vocab.len() as i32;
        vocab.push(b"<eos>".to_vec());
        Self { merges, vocab, eos }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    /// Encode text (repeatedly applying merges until fixpoint).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = Vec::new();
        for word in text.split_inclusive(' ') {
            let mut s: Vec<i32> = word.bytes().map(|b| b as i32).collect();
            loop {
                let mut best: Option<(usize, i32)> = None;
                for (i, w) in s.windows(2).enumerate() {
                    if let Some(&id) = self.merges.get(&(w[0], w[1])) {
                        if best.map(|(_, b)| id < b).unwrap_or(true) {
                            best = Some((i, id));
                        }
                    }
                }
                match best {
                    Some((i, id)) => {
                        s[i] = id;
                        s.remove(i + 1);
                    }
                    None => break,
                }
            }
            out.extend(s);
        }
        out
    }

    pub fn decode(&self, tokens: &[i32]) -> Result<String> {
        let mut bytes = Vec::new();
        for &t in tokens {
            if t == self.eos {
                break;
            }
            if let Some(v) = self.vocab.get(t as usize) {
                bytes.extend_from_slice(v);
            }
        }
        Ok(String::from_utf8_lossy(&bytes).into_owned())
    }
}

/// A small built-in corpus so examples produce real token streams without
/// external downloads (the instruction-following flavor mirrors Alpaca).
pub const TINY_CORPUS: &str = "\
Below is an instruction that describes a task. Write a response that \
appropriately completes the request. Instruction: Give three tips for \
staying healthy. Response: Eat a balanced diet and make sure to include \
plenty of fruits and vegetables. Exercise regularly to keep your body \
active and strong. Get enough sleep and maintain a consistent sleep \
schedule. Instruction: What are the three primary colors? Response: The \
three primary colors are red, blue, and yellow. Instruction: Describe the \
structure of an atom. Response: An atom is made up of a nucleus, which \
contains protons and neutrons, surrounded by electrons that travel in \
orbits around the nucleus. Instruction: How can we reduce air pollution? \
Response: There are several ways to reduce air pollution, such as \
shifting to renewable energy sources, encouraging the use of public \
transport, and planting more trees. Instruction: Solve the math problem. \
Natalia sold clips to 48 of her friends in April, and then she sold half \
as many clips in May. How many clips did Natalia sell altogether? \
Response: Natalia sold 48 clips in April and 24 clips in May, so she sold \
72 clips altogether. The answer is 72.";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_on_corpus_words() {
        let tok = Tokenizer::train(TINY_CORPUS, 512);
        assert!(tok.vocab_size() <= 512);
        for text in ["instruction", "the three primary colors", "Natalia sold 48 clips"] {
            let ids = tok.encode(text);
            assert_eq!(tok.decode(&ids).unwrap(), text);
        }
    }

    #[test]
    fn merges_compress() {
        let tok = Tokenizer::train(TINY_CORPUS, 512);
        let text = "instruction response instruction response";
        let ids = tok.encode(text);
        assert!(ids.len() < text.len(), "{} !< {}", ids.len(), text.len());
    }

    #[test]
    fn all_ids_in_vocab_range() {
        let tok = Tokenizer::train(TINY_CORPUS, 512);
        let ids = tok.encode(TINY_CORPUS);
        assert!(ids.iter().all(|&t| (t as usize) < tok.vocab_size()));
    }

    #[test]
    fn decode_stops_at_eos() {
        let tok = Tokenizer::train(TINY_CORPUS, 512);
        let mut ids = tok.encode("hello");
        ids.push(tok.eos);
        ids.extend(tok.encode("world"));
        assert_eq!(tok.decode(&ids).unwrap(), "hello");
    }
}
