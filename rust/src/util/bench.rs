//! Micro-benchmark harness (criterion is unavailable offline): warmup,
//! timed iterations, mean/p50/p99, throughput, and a stable one-line
//! report format consumed by EXPERIMENTS.md §Perf.

use std::time::Instant;

/// The audited choke point for wall-clock reads outside this module.
///
/// The determinism discipline (DESIGN.md §13, `wall-clock` rule) is that
/// engine/coordinator/server code never schedules on real time — the
/// coordinator's virtual clock owns ordering. Real durations are still
/// *reported* (frame timings, `ExecTiming`, trajectory rows), and all of
/// those measurements start here, so there is exactly one reviewed place
/// where `Instant` enters the tree.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    /// Seconds since `start()`, for human-facing stats frames.
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    /// Whole microseconds since `start()`, for `ExecTiming`-style rows.
    pub fn elapsed_us(&self) -> u64 {
        self.0.elapsed().as_micros() as u64
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub min_us: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<42} iters={:<6} mean={:>10.1}µs p50={:>10.1}µs p99={:>10.1}µs min={:>10.1}µs",
            self.name, self.iters, self.mean_us, self.p50_us, self.p99_us, self.min_us
        );
    }

    pub fn per_sec(&self) -> f64 {
        1e6 / self.mean_us.max(1e-9)
    }
}

/// Run `f` for `warmup` unrecorded + `iters` recorded iterations.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    summarize(name, samples)
}

/// Time-budgeted variant: run until `budget_s` elapses (min 10 iters).
pub fn bench_for(name: &str, budget_s: f64, mut f: impl FnMut()) -> BenchResult {
    // Warmup once.
    f();
    let start = Instant::now();
    let mut samples = Vec::new();
    while start.elapsed().as_secs_f64() < budget_s || samples.len() < 10 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
        if samples.len() > 100_000 {
            break;
        }
    }
    summarize(name, samples)
}

fn summarize(name: &str, mut samples: Vec<f64>) -> BenchResult {
    samples.sort_by(|a, b| a.total_cmp(b));
    let n = samples.len().max(1);
    let mean = samples.iter().sum::<f64>() / n as f64;
    let q = |p: f64| samples[((p * (n - 1) as f64) as usize).min(n - 1)];
    let r = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_us: mean,
        p50_us: q(0.5),
        p99_us: q(0.99),
        min_us: samples.first().copied().unwrap_or(0.0),
    };
    r.print();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let t = Stopwatch::start();
        let a = t.elapsed_us();
        let b = t.elapsed_us();
        assert!(b >= a);
        assert!(t.elapsed_s() >= 0.0);
    }

    #[test]
    fn quantiles_ordered() {
        let r = bench("noop", 2, 50, || { std::hint::black_box(1 + 1); });
        assert!(r.min_us <= r.p50_us && r.p50_us <= r.p99_us);
        assert_eq!(r.iters, 50);
    }
}
