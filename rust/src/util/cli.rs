//! Tiny CLI flag parser: `--key value`, `--flag`, positional args.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(items: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = args("serve --rps 3.5 --fast --out=x.json trace.bin");
        assert_eq!(a.positional, ["serve", "trace.bin"]);
        assert_eq!(a.f64_or("rps", 1.0).unwrap(), 3.5);
        assert!(a.bool("fast"));
        assert_eq!(a.str_or("out", ""), "x.json");
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn bad_number_is_error() {
        let a = args("--n abc");
        assert!(a.usize_or("n", 0).is_err());
    }
}
