//! Tiny CLI flag parser: `--key value`, `--flag`, positional args — plus
//! the shared `--backend` and `--policy` selectors.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::coordinator::PolicyKind;

/// Which execution backend a command should construct (`--backend`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust CPU numerics over a random-weight tiny model (or any
    /// `WeightStore`-shaped weights) — no artifacts, no PJRT.
    Native,
    /// AOT artifacts on the PJRT CPU client (requires `make artifacts`
    /// and the real `xla` bindings).
    Xla,
}

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(items: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Parse `--backend native|xla` (defaulting when absent).
    pub fn backend_or(&self, default: BackendKind) -> Result<BackendKind> {
        match self.get("backend") {
            None => Ok(default),
            Some("native") => Ok(BackendKind::Native),
            Some("xla") => Ok(BackendKind::Xla),
            Some(other) => Err(anyhow!("--backend: unknown backend '{other}' (native|xla)")),
        }
    }

    /// Parse `--threads N` for the native backend's worker pool. Absent
    /// (or `0`) means auto: `NativeBackend::new` resolves it via
    /// `runtime::parallel::resolve_threads` (the `LOQUETIER_THREADS` env
    /// var, else available parallelism).
    pub fn threads_or_auto(&self) -> Result<usize> {
        self.usize_or("threads", 0)
    }

    /// `--quantized` — serve base weights as per-row int8 on the native
    /// backend (DESIGN.md §11). Training and the XLA backend ignore it.
    pub fn quantized(&self) -> bool {
        self.bool("quantized")
    }

    /// Parse `--policy fifo|slo` — which scheduling policy the coordinator
    /// plans with (DESIGN.md §9). The PEFT policy is a baseline-internal
    /// configuration, not a CLI surface.
    pub fn policy_or(&self, default: PolicyKind) -> Result<PolicyKind> {
        match self.get("policy") {
            None => Ok(default),
            Some("fifo") => Ok(PolicyKind::Fifo),
            Some("slo") => Ok(PolicyKind::SloAware),
            Some(other) => Err(anyhow!("--policy: unknown policy '{other}' (fifo|slo)")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = args("serve --rps 3.5 --fast --out=x.json trace.bin");
        assert_eq!(a.positional, ["serve", "trace.bin"]);
        assert_eq!(a.f64_or("rps", 1.0).unwrap(), 3.5);
        assert!(a.bool("fast"));
        assert_eq!(a.str_or("out", ""), "x.json");
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn bad_number_is_error() {
        let a = args("--n abc");
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn threads_flag_parses_with_auto_default() {
        assert_eq!(args("--threads 4").threads_or_auto().unwrap(), 4);
        assert_eq!(args("").threads_or_auto().unwrap(), 0, "absent = 0 = auto");
        assert!(args("--threads lots").threads_or_auto().is_err());
    }

    #[test]
    fn quantized_is_a_bare_flag() {
        assert!(args("--quantized").quantized());
        assert!(args("--quantized true").quantized());
        assert!(!args("").quantized());
    }

    #[test]
    fn policy_selector_parses() {
        assert_eq!(
            args("--policy slo").policy_or(PolicyKind::Fifo).unwrap(),
            PolicyKind::SloAware
        );
        assert_eq!(
            args("--policy fifo").policy_or(PolicyKind::SloAware).unwrap(),
            PolicyKind::Fifo
        );
        assert_eq!(args("").policy_or(PolicyKind::Fifo).unwrap(), PolicyKind::Fifo);
        assert!(args("--policy edf").policy_or(PolicyKind::Fifo).is_err());
    }

    #[test]
    fn backend_selector_parses() {
        assert_eq!(
            args("--backend native").backend_or(BackendKind::Xla).unwrap(),
            BackendKind::Native
        );
        assert_eq!(
            args("--backend xla").backend_or(BackendKind::Native).unwrap(),
            BackendKind::Xla
        );
        assert_eq!(args("").backend_or(BackendKind::Native).unwrap(), BackendKind::Native);
        assert!(args("--backend gpu").backend_or(BackendKind::Xla).is_err());
    }
}
