//! Minimal JSON codec (parser + writer), built in-tree because the offline
//! environment ships no serde/serde_json (DESIGN.md §3).
//!
//! Supports the full JSON grammar needed by the AOT manifest, golden files,
//! calibration files and the serving wire protocol: objects, arrays,
//! strings (with \uXXXX escapes), numbers, booleans, null. Object key order
//! is preserved (manifest entry order == compile order).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ---------------------------------------------------------- accessors
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Ok(v),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Shorthand: array of usize.
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Shorthand: array of f32 (tolerates integer literals).
    pub fn f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }

    pub fn i32_vec(&self) -> Result<Vec<i32>> {
        self.as_arr()?
            .iter()
            .map(|v| {
                let n = v.as_f64()?;
                if n.fract() != 0.0 {
                    bail!("not an integer: {n}");
                }
                Ok(n as i32)
            })
            .collect()
    }

    // --------------------------------------------------------- constructors
    pub fn obj(kvs: Vec<(&str, Json)>) -> Json {
        Json::Obj(kvs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64s(v: impl IntoIterator<Item = f64>) -> Json {
        Json::Arr(v.into_iter().map(Json::Num).collect())
    }

    pub fn from_strs(v: impl IntoIterator<Item = String>) -> Json {
        Json::Arr(v.into_iter().map(Json::Str).collect())
    }

    pub fn from_map(m: &BTreeMap<String, f64>) -> Json {
        Json::Obj(m.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect())
    }

    // ------------------------------------------------------------ writing
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut kvs = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kvs.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(kvs));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-sync to char boundaries for multibyte UTF-8.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = utf8_width(c);
                        let end = start + width;
                        if end > self.b.len() {
                            bail!("truncated utf-8");
                        }
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number '{text}': {e}"))?))
    }
}

fn utf8_width(b: u8) -> usize {
    if b >= 0xF0 {
        4
    } else if b >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2.5,-3],"b":{"c":"hi \"x\"","d":null},"e":true}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().usize_vec().is_err(), true); // 2.5
        assert_eq!(v.get("e").unwrap().as_bool().unwrap(), true);
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn parses_scientific_and_unicode() {
        let v = parse(r#"{"x":1e-3,"s":"été"}"#).unwrap();
        assert!((v.get("x").unwrap().as_f64().unwrap() - 1e-3).abs() < 1e-12);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "été");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{}x").is_err());
    }

    #[test]
    fn big_flat_arrays() {
        let n = 10_000;
        let src = format!("[{}]", (0..n).map(|i| i.to_string()).collect::<Vec<_>>().join(","));
        let v = parse(&src).unwrap();
        assert_eq!(v.as_arr().unwrap().len(), n);
    }

    #[test]
    fn multibyte_utf8_passthrough() {
        let v = parse(r#"{"s":"héllo 世界"}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "héllo 世界");
    }
}
