//! In-tree substrates for the offline environment (no serde/rand/clap/
//! criterion/proptest available): JSON codec, PRNG + distributions, CLI
//! flag parsing, a micro-bench harness, and a property-test driver.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
