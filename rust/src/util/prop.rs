//! Property-test driver (proptest is unavailable offline): run a property
//! over many seeded random cases; on failure, report the seed so the case
//! replays deterministically, and shrink integer parameters greedily.

use crate::util::rng::Rng;

/// Run `prop(rng)` for `cases` seeds. `prop` returns Err(description) on a
/// violated property. Panics with the failing seed (re-run with
/// `replay(seed, prop)` to debug).
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Rng) -> Result<(), String>) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::seed_from_u64(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing seed.
pub fn replay(seed: u64, prop: impl Fn(&mut Rng) -> Result<(), String>) {
    let mut rng = Rng::seed_from_u64(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("replay(seed {seed:#x}) failed: {msg}");
    }
}

/// Greedy shrink over one usize parameter: find the smallest `n` in
/// [lo, hi] for which `fails(n)` still holds (assumes monotonicity; a
/// pragmatic shrinker, not a general one).
pub fn shrink_usize(lo: usize, hi: usize, fails: impl Fn(usize) -> bool) -> Option<usize> {
    if !fails(hi) {
        return None;
    }
    let (mut lo, mut hi) = (lo, hi);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if fails(mid) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    Some(hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_quiet() {
        check("sum-commutes", 50, |rng| {
            let a = rng.range(-100, 100);
            let b = rng.range(-100, 100);
            if a + b == b + a {
                Ok(())
            } else {
                Err("addition does not commute?!".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn shrinker_finds_boundary() {
        assert_eq!(shrink_usize(0, 100, |n| n >= 37), Some(37));
        assert_eq!(shrink_usize(0, 100, |_| false), None);
    }
}
