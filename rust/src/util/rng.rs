//! Deterministic PRNG + the sampling distributions the workload generators
//! need (exponential, log-normal, normal) — in-tree because the offline
//! environment ships no `rand`/`rand_distr` (DESIGN.md §3).

/// xoshiro256** — fast, high-quality, seedable.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        // splitmix64 expansion, the standard seeding for xoshiro.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.s;
        let result = s1.wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s1 << 17;
        let mut s = [s0, s1, s2, s3];
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        self.s = s;
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1), never exactly 0 (safe for ln()).
    fn f64_open(&mut self) -> f64 {
        loop {
            let v = self.f64();
            if v > 0.0 {
                return v;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as i64, hi as i64) as usize
    }

    /// Exponential with the given rate (mean 1/rate).
    pub fn exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -self.f64_open().ln() / rate
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64_open();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with log-space mean `mu` and std `sigma`.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Bernoulli.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_half() {
        let mut r = Rng::seed_from_u64(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Rng::seed_from_u64(2);
        let rate = 4.0;
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::seed_from_u64(4);
        let n = 50_001;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(5.2, 0.9)).collect();
        xs.sort_by(|a, b| a.total_cmp(b));
        let median = xs[n / 2];
        // lognormal median = exp(mu)
        assert!((median / 5.2f64.exp().powf(1.0) - 1.0).abs() < 0.1 || true);
        assert!((median - (5.2f64).exp()).abs() / (5.2f64).exp() < 0.1, "median {median}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::seed_from_u64(5);
        for _ in 0..1000 {
            let v = r.range(-3, 9);
            assert!((-3..9).contains(&v));
        }
    }
}
