//! Arrival processes: Poisson (Figures 2/4) and phase schedules (Figure 5 /
//! Table 7). The BurstGPT synthesizer lives in `burstgpt.rs`.

use crate::util::rng::Rng;

/// A stateful arrival-time generator.
pub trait ArrivalProcess {
    /// Next arrival time in seconds (monotone non-decreasing).
    fn next_arrival(&mut self, rng: &mut Rng) -> f64;
}

/// Poisson arrivals at a constant rate (requests/second).
#[derive(Debug, Clone)]
pub struct PoissonArrivals {
    rate: f64,
    t: f64,
}

impl PoissonArrivals {
    pub fn new(rate_rps: f64) -> Self {
        assert!(rate_rps > 0.0);
        Self { rate: rate_rps, t: 0.0 }
    }
}

impl ArrivalProcess for PoissonArrivals {
    fn next_arrival(&mut self, rng: &mut Rng) -> f64 {
        self.t += rng.exp(self.rate);
        self.t
    }
}

/// One phase of the mutable-load schedule (Table 7 rows).
#[derive(Debug, Clone, Copy)]
pub struct MutablePhase {
    pub start_s: f64,
    pub duration_s: f64,
    pub rate_rps: f64,
    /// Adapter index this phase's requests target.
    pub adapter: i32,
    pub requests: usize,
}

/// Piecewise schedule: each phase emits its own Poisson stream within its
/// window. Used as-is for Figure 5.
#[derive(Debug, Clone)]
pub struct ScheduleArrivals {
    phases: Vec<MutablePhase>,
    cursor: usize,
    emitted_in_phase: usize,
    t: f64,
}

impl ScheduleArrivals {
    pub fn new(phases: Vec<MutablePhase>) -> Self {
        let t = phases.first().map(|p| p.start_s).unwrap_or(0.0);
        Self { phases, cursor: 0, emitted_in_phase: 0, t }
    }

    /// The phase the *next* arrival belongs to (for adapter routing).
    pub fn current_adapter(&self) -> i32 {
        self.phases
            .get(self.cursor.min(self.phases.len().saturating_sub(1)))
            .map(|p| p.adapter)
            .unwrap_or(-1)
    }

    pub fn total_requests(&self) -> usize {
        self.phases.iter().map(|p| p.requests).sum()
    }
}

impl ArrivalProcess for ScheduleArrivals {
    fn next_arrival(&mut self, rng: &mut Rng) -> f64 {
        while self.cursor < self.phases.len() {
            let p = self.phases[self.cursor];
            if self.emitted_in_phase >= p.requests {
                self.cursor += 1;
                self.emitted_in_phase = 0;
                if let Some(np) = self.phases.get(self.cursor) {
                    self.t = self.t.max(np.start_s);
                }
                continue;
            }
            self.t = (self.t + rng.exp(p.rate_rps)).max(p.start_s);
            self.emitted_in_phase += 1;
            return self.t;
        }
        // Exhausted: keep returning increasing times.
        self.t += 1.0;
        self.t
    }
}

/// Table 7 of the paper: the mutable unified-task schedule.
pub fn table7_schedule() -> Vec<MutablePhase> {
    vec![
        MutablePhase { start_s: 0.0, duration_s: 120.0, rate_rps: 1.0, adapter: 0, requests: 120 },
        MutablePhase { start_s: 120.0, duration_s: 60.0, rate_rps: 2.5, adapter: 1, requests: 150 },
        MutablePhase { start_s: 180.0, duration_s: 120.0, rate_rps: 2.0, adapter: 2, requests: 240 },
        MutablePhase { start_s: 300.0, duration_s: 120.0, rate_rps: 1.0, adapter: 3, requests: 120 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_respected() {
        let mut p = PoissonArrivals::new(4.0);
        let mut rng = Rng::seed_from_u64(0);
        let mut last = 0.0;
        let n = 4000;
        for _ in 0..n {
            let t = p.next_arrival(&mut rng);
            assert!(t >= last);
            last = t;
        }
        let rate = n as f64 / last;
        assert!((3.5..4.5).contains(&rate), "rate {rate}");
    }

    #[test]
    fn schedule_emits_phase_counts_in_windows() {
        let mut s = ScheduleArrivals::new(table7_schedule());
        let mut rng = Rng::seed_from_u64(1);
        let total = s.total_requests();
        let mut times = Vec::new();
        for _ in 0..total {
            times.push(s.next_arrival(&mut rng));
        }
        assert_eq!(times.len(), 630);
        // Phase 2 requests land at/after its start.
        assert!(times[120] >= 120.0);
        for w in times.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn schedule_adapter_follows_phase() {
        let mut s = ScheduleArrivals::new(table7_schedule());
        let mut rng = Rng::seed_from_u64(2);
        assert_eq!(s.current_adapter(), 0);
        for _ in 0..120 {
            s.next_arrival(&mut rng);
        }
        assert_eq!(s.current_adapter(), 0); // cursor advances on *next* call
        s.next_arrival(&mut rng);
        assert_eq!(s.current_adapter(), 1);
    }
}
