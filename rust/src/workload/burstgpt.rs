//! BurstGPT-style workload synthesizer (Figure 6 / Table 8).
//!
//! The paper slices the BurstGPT Azure-GPT trace into 20-minute windows and
//! replays six of them (Table 8: per-slice request count, mean RPS, peak
//! 2-second RPS). We cannot ship the proprietary trace, so we synthesize
//! slices with the same statistics: a doubly-stochastic (Markov-modulated)
//! Poisson process whose burst state reproduces the published mean *and*
//! peak rates — bursts are what stress the capacity allocator, and the peak
//! column is exactly the paper's "transient spikes exceeding RPS 10".

use crate::util::rng::Rng;

/// One Table-8 slice.
#[derive(Debug, Clone, Copy)]
pub struct BurstGptSlice {
    pub label: &'static str,
    pub requests: usize,
    pub mean_rps: f64,
    pub peak_rps: f64,
}

/// Table 8 of the paper, verbatim.
pub const TABLE8_SLICES: [BurstGptSlice; 6] = [
    BurstGptSlice { label: "Day29 13:00", requests: 676, mean_rps: 0.563, peak_rps: 1.5 },
    BurstGptSlice { label: "Day29 15:00", requests: 2145, mean_rps: 1.788, peak_rps: 11.5 },
    BurstGptSlice { label: "Day29 16:00", requests: 1465, mean_rps: 1.226, peak_rps: 7.0 },
    BurstGptSlice { label: "Day33 13:40", requests: 2823, mean_rps: 2.354, peak_rps: 10.0 },
    BurstGptSlice { label: "Day33 11:40", requests: 2360, mean_rps: 1.966, peak_rps: 12.0 },
    BurstGptSlice { label: "Day33 11:00", requests: 1856, mean_rps: 1.547, peak_rps: 10.5 },
];

/// Markov-modulated Poisson synthesizer for one slice.
#[derive(Debug, Clone)]
pub struct BurstGptSynth {
    slice: BurstGptSlice,
    /// Probability of being in the burst state.
    burst_prob: f64,
    base_rate: f64,
    burst_rate: f64,
    /// Mean burst duration (seconds).
    burst_len_s: f64,
    t: f64,
    in_burst_until: f64,
    next_burst_at: f64,
}

impl BurstGptSynth {
    pub fn new(slice: BurstGptSlice) -> Self {
        // Choose base/burst rates so that:
        //   mean = (1-p)*base + p*burst,   burst ≈ peak * 0.8 (peak is a
        //   2-second max, the sustained burst rate sits slightly below it).
        let burst_rate = (slice.peak_rps * 0.8).max(slice.mean_rps);
        // Low-load slices (peak < 3 RPS) are flat in the trace: plain
        // Poisson already reproduces their 2-second peaks.
        let p = if burst_rate > slice.mean_rps && slice.peak_rps >= 3.0 {
            // Keep ~15% of time bursty unless the slice is flat.
            (0.15f64).min(slice.mean_rps / burst_rate)
        } else {
            0.0
        };
        let base_rate = if p < 1.0 {
            ((slice.mean_rps - p * burst_rate) / (1.0 - p)).max(0.05)
        } else {
            slice.mean_rps
        };
        Self {
            slice,
            burst_prob: p,
            base_rate,
            burst_rate,
            burst_len_s: 6.0,
            t: 0.0,
            in_burst_until: 0.0,
            next_burst_at: 0.0,
        }
    }

    pub fn slice(&self) -> &BurstGptSlice {
        &self.slice
    }

    fn rate_at(&mut self, t: f64, rng: &mut Rng) -> f64 {
        if t < self.in_burst_until {
            return self.burst_rate;
        }
        if t >= self.next_burst_at {
            // Schedule the next burst: exponential inter-burst gap sized so
            // the long-run burst fraction is `burst_prob`.
            if self.burst_prob > 0.0 {
                let gap_mean = self.burst_len_s * (1.0 - self.burst_prob) / self.burst_prob;
                let gap = rng.exp(1.0 / gap_mean.max(0.1));
                self.in_burst_until = t + self.burst_len_s;
                self.next_burst_at = self.in_burst_until + gap;
                return self.burst_rate;
            }
        }
        self.base_rate
    }

    /// Generate all arrivals for the slice (seconds from slice start).
    pub fn arrivals(&mut self, rng: &mut Rng) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.slice.requests);
        while out.len() < self.slice.requests {
            let rate = self.rate_at(self.t, rng);
            let dt = rng.exp(rate);
            self.t += dt;
            out.push(self.t);
        }
        out
    }
}

/// Check of a generated arrival vector: (mean RPS, peak 2-second RPS).
pub fn trace_stats(arrivals: &[f64]) -> (f64, f64) {
    if arrivals.is_empty() {
        return (0.0, 0.0);
    }
    let horizon = arrivals.last().unwrap().max(1e-9);
    let mean = arrivals.len() as f64 / horizon;
    let mut peak = 0usize;
    let mut lo = 0usize;
    for hi in 0..arrivals.len() {
        while arrivals[hi] - arrivals[lo] > 2.0 {
            lo += 1;
        }
        peak = peak.max(hi - lo + 1);
    }
    (mean, peak as f64 / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthesized_slices_match_table8_stats() {
        let mut rng = Rng::seed_from_u64(42);
        for slice in TABLE8_SLICES {
            let mut synth = BurstGptSynth::new(slice);
            let arr = synth.arrivals(&mut rng);
            assert_eq!(arr.len(), slice.requests);
            let (mean, peak) = trace_stats(&arr);
            assert!(
                (mean - slice.mean_rps).abs() / slice.mean_rps < 0.35,
                "{}: mean {mean:.3} vs {}",
                slice.label,
                slice.mean_rps
            );
            // Peak must reach at least ~60% of the published peak (bursts
            // exist) and not wildly exceed it.
            // Sliding-window Poisson peaks have heavy tails; allow slack
            // above (clusters) and below (single seed) the published value.
            assert!(
                peak >= slice.peak_rps * 0.4 && peak <= slice.peak_rps * 2.5 + 3.0,
                "{}: peak {peak:.1} vs {}",
                slice.label,
                slice.peak_rps
            );
        }
    }

    #[test]
    fn arrivals_are_monotone() {
        let mut synth = BurstGptSynth::new(TABLE8_SLICES[1]);
        let mut rng = Rng::seed_from_u64(0);
        let arr = synth.arrivals(&mut rng);
        for w in arr.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn high_load_slices_do_burst_above_5rps() {
        // The paper: "transient spikes exceeding RPS 10" / failures occur
        // only when RPS > 5. Our synthesizer must produce such spikes for
        // the high-load slices.
        let mut rng = Rng::seed_from_u64(7);
        let mut synth = BurstGptSynth::new(TABLE8_SLICES[3]); // 2.354 mean / 10 peak
        let arr = synth.arrivals(&mut rng);
        let (_, peak) = trace_stats(&arr);
        assert!(peak > 5.0, "peak {peak}");
    }
}
