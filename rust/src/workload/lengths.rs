//! Request-length models matching the published statistics of the datasets
//! the paper uses (ShareGPT for inference, Alpaca/GSM8K for fine-tuning).
//!
//! Lengths are sampled from a log-normal clipped to [min, max] — the shape
//! repeatedly reported for ShareGPT prompt lengths — with parameters chosen
//! to hit each dataset's published mean/median. Only the *distribution*
//! matters for the figures (queueing + batching behaviour), not the text.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct LengthModel {
    /// Mean of log(length).
    pub mu: f64,
    /// Std of log(length).
    pub sigma: f64,
    pub min: usize,
    pub max: usize,
}

impl LengthModel {
    pub fn sample_prompt(&self, rng: &mut Rng) -> usize {
        let v = rng.lognormal(self.mu, self.sigma);
        (v as usize).clamp(self.min, self.max)
    }

    /// Scale a published-token-scale model to this build's bucket scale.
    /// E.g. ShareGPT's ~250-token mean scaled into a 64-token prompt budget.
    pub fn rescaled_to(&self, target_mean: f64) -> LengthModel {
        // lognormal mean = exp(mu + sigma^2/2)
        let cur_mean = (self.mu + self.sigma * self.sigma / 2.0).exp();
        let shift = (target_mean / cur_mean).ln();
        LengthModel {
            mu: self.mu + shift,
            sigma: self.sigma,
            min: self.min,
            max: ((self.max as f64) * target_mean / cur_mean).ceil() as usize,
        }
    }
}

/// ShareGPT conversation turns: heavy-tailed, mean ≈ 250 tokens.
pub const SHAREGPT_LENGTHS: LengthModel =
    LengthModel { mu: 5.2, sigma: 0.9, min: 8, max: 2048 };

/// Alpaca instruction+output: mean ≈ 90 tokens, lighter tail.
pub const ALPACA_LENGTHS: LengthModel =
    LengthModel { mu: 4.3, sigma: 0.6, min: 8, max: 512 };

/// GSM8K question+solution: mean ≈ 180 tokens, narrow.
pub const GSM8K_LENGTHS: LengthModel =
    LengthModel { mu: 5.1, sigma: 0.35, min: 32, max: 512 };

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_respect_bounds() {
        let mut rng = Rng::seed_from_u64(0);
        for m in [SHAREGPT_LENGTHS, ALPACA_LENGTHS, GSM8K_LENGTHS] {
            for _ in 0..500 {
                let v = m.sample_prompt(&mut rng);
                assert!(v >= m.min && v <= m.max);
            }
        }
    }

    #[test]
    fn sharegpt_mean_near_published() {
        let mut rng = Rng::seed_from_u64(1);
        let n = 20_000;
        let s: usize = (0..n).map(|_| SHAREGPT_LENGTHS.sample_prompt(&mut rng)).sum();
        let mean = s as f64 / n as f64;
        assert!((150.0..350.0).contains(&mean), "mean {mean}");
    }

    #[test]
    fn rescaling_hits_target_mean() {
        let m = SHAREGPT_LENGTHS.rescaled_to(40.0);
        let mut rng = Rng::seed_from_u64(2);
        let n = 20_000;
        let s: usize = (0..n).map(|_| m.sample_prompt(&mut rng)).sum();
        let mean = s as f64 / n as f64;
        assert!((25.0..55.0).contains(&mean), "mean {mean}");
    }
}
