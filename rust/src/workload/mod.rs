//! Workload generation: request length models and arrival processes for
//! every experiment in the paper (DESIGN.md §3 records the dataset
//! substitutions — the figures depend on length/arrival *distributions*,
//! which we reproduce from each dataset's published statistics).

mod arrivals;
mod burstgpt;
mod lengths;

pub use arrivals::{table7_schedule, ArrivalProcess, MutablePhase, PoissonArrivals, ScheduleArrivals};
pub use burstgpt::{trace_stats, BurstGptSlice, BurstGptSynth, TABLE8_SLICES};
pub use lengths::{LengthModel, ALPACA_LENGTHS, GSM8K_LENGTHS, SHAREGPT_LENGTHS};

use crate::coordinator::{InferenceRequest, TrainExample};
use crate::util::rng::Rng;

/// A fully materialized inference trace (arrival-sorted).
#[derive(Debug, Clone)]
pub struct Trace {
    pub requests: Vec<InferenceRequest>,
}

impl Trace {
    pub fn duration_s(&self) -> f64 {
        self.requests.last().map(|r| r.arrival_s).unwrap_or(0.0)
    }
}

/// Build an inference trace: `n` requests across `adapters`, arrivals from
/// `arrivals`, prompt lengths from `lengths`, fixed `max_new` (the paper's
/// Appendix D.2/D.4 tables fix max-new per RPS row).
#[allow(clippy::too_many_arguments)]
pub fn build_trace(
    seed: u64,
    n: usize,
    adapters: &[i32],
    arrivals: &mut dyn ArrivalProcess,
    lengths: &LengthModel,
    max_new: usize,
    max_prompt: usize,
    vocab: i32,
) -> Trace {
    let mut rng = Rng::seed_from_u64(seed);
    let mut requests = Vec::with_capacity(n);
    for i in 0..n {
        let arrival_s = arrivals.next_arrival(&mut rng);
        let len = lengths.sample_prompt(&mut rng).clamp(1, max_prompt);
        let prompt: Vec<i32> = (0..len).map(|k| ((i * 131 + k * 7 + 3) as i32) % vocab).collect();
        requests.push(InferenceRequest {
            id: i as u64,
            adapter: adapters[i % adapters.len()],
            prompt,
            max_new_tokens: max_new,
            eos_token: None,
            arrival_s,
            slo: None,
        });
    }
    requests.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    Trace { requests }
}

/// Seeded per-adapter system-prefix pool (DESIGN.md §14): each adapter
/// owns ONE fixed system prompt of `prefix_len` tokens, and a request
/// reuses its adapter's prefix with probability `reuse_p` (otherwise the
/// prompt is fully per-request, the pre-§14 synthesis). Without a pool no
/// two requests can ever share a prefix — the old per-request formula
/// (`(i*131 + k*7 + 3) % vocab`) salts every token with the request index.
#[derive(Debug, Clone)]
pub struct PrefixPool {
    prefixes: Vec<Vec<i32>>,
    reuse_p: f64,
}

impl PrefixPool {
    pub fn new(seed: u64, n_adapters: usize, prefix_len: usize, reuse_p: f64, vocab: i32) -> Self {
        assert!(n_adapters > 0, "need at least one adapter");
        assert!((0.0..=1.0).contains(&reuse_p), "reuse_p is a probability");
        let mut rng = Rng::seed_from_u64(seed);
        let prefixes = (0..n_adapters)
            .map(|_| (0..prefix_len).map(|_| (rng.next_u64() % vocab.max(1) as u64) as i32).collect())
            .collect();
        Self { prefixes, reuse_p }
    }

    /// This adapter's system prefix (for tests and hit-rate accounting).
    pub fn prefix(&self, adapter: i32) -> &[i32] {
        &self.prefixes[adapter.max(0) as usize % self.prefixes.len()]
    }

    /// Synthesize one prompt of exactly `len` tokens: the adapter's shared
    /// prefix (clipped to `len`) plus a per-request tail, or — with
    /// probability `1 - reuse_p` — a fully per-request prompt using the
    /// exact pre-§14 formula. The length distribution is untouched either
    /// way; only token *content* changes.
    pub fn prompt(&self, rng: &mut Rng, adapter: i32, len: usize, salt: usize, vocab: i32) -> Vec<i32> {
        let fresh = |k: usize| ((salt * 131 + k * 7 + 3) as i32) % vocab;
        if !rng.chance(self.reuse_p) {
            return (0..len).map(fresh).collect();
        }
        let pfx = self.prefix(adapter);
        let shared = len.min(pfx.len());
        let mut prompt = pfx[..shared].to_vec();
        prompt.extend((shared..len).map(fresh));
        prompt
    }
}

/// Multi-tenant trace for the shared-prefix experiments: `n` requests
/// round-robin over `n_adapters` adapters, each adapter carrying a fixed
/// `prefix_tokens`-long system prompt its requests reuse with probability
/// `reuse_p`. Arrival and length models are the standard ones — only the
/// prompt content differs from [`build_trace`].
#[allow(clippy::too_many_arguments)]
pub fn build_tenant_trace(
    seed: u64,
    n: usize,
    n_adapters: usize,
    arrivals: &mut dyn ArrivalProcess,
    lengths: &LengthModel,
    prefix_tokens: usize,
    reuse_p: f64,
    max_new: usize,
    max_prompt: usize,
    vocab: i32,
) -> Trace {
    let pool = PrefixPool::new(seed ^ 0x5eed_cafe, n_adapters, prefix_tokens, reuse_p, vocab);
    let mut rng = Rng::seed_from_u64(seed);
    let mut requests = Vec::with_capacity(n);
    for i in 0..n {
        let arrival_s = arrivals.next_arrival(&mut rng);
        let adapter = (i % n_adapters) as i32;
        let len = lengths.sample_prompt(&mut rng).clamp(1, max_prompt);
        let prompt = pool.prompt(&mut rng, adapter, len, i, vocab);
        requests.push(InferenceRequest {
            id: i as u64,
            adapter,
            prompt,
            max_new_tokens: max_new,
            eos_token: None,
            arrival_s,
            slo: None,
        });
    }
    requests.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    Trace { requests }
}

/// Zipfian adapter popularity: adapter id `k` (0-based rank) is drawn with
/// probability ∝ 1/(k+1)^s. This is the multi-tenant serving regime the
/// unified adapter-paging tier targets (DESIGN.md §10): thousands of
/// registered adapters, a small hot head that covers most traffic, and a
/// long cold tail that must live in the host tier between requests.
#[derive(Debug, Clone)]
pub struct ZipfAdapters {
    /// Cumulative probability by rank; `cdf.last() == 1.0`.
    cdf: Vec<f64>,
}

impl ZipfAdapters {
    pub fn new(n_adapters: usize, s: f64) -> Self {
        assert!(n_adapters > 0, "need at least one adapter");
        let mut cdf = Vec::with_capacity(n_adapters);
        let mut acc = 0.0;
        for k in 0..n_adapters {
            acc += 1.0 / ((k + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        *cdf.last_mut().unwrap() = 1.0;
        Self { cdf }
    }

    /// Draw one adapter id in `0..n_adapters` (rank order: 0 is hottest).
    pub fn sample(&self, rng: &mut Rng) -> i32 {
        let u = rng.f64();
        // First rank whose cumulative mass exceeds u.
        match self.cdf.binary_search_by(|c| c.total_cmp(&u)) {
            Ok(i) | Err(i) => i.min(self.cdf.len() - 1) as i32,
        }
    }
}

/// Build an inference trace whose adapter ids follow a Zipfian popularity
/// law over `n_adapters` tenants (instead of `build_trace`'s round-robin).
#[allow(clippy::too_many_arguments)]
pub fn build_zipf_trace(
    seed: u64,
    n: usize,
    n_adapters: usize,
    zipf_s: f64,
    arrivals: &mut dyn ArrivalProcess,
    lengths: &LengthModel,
    max_new: usize,
    max_prompt: usize,
    vocab: i32,
) -> Trace {
    build_zipf_trace_shared(
        seed, n, n_adapters, zipf_s, arrivals, lengths, max_new, max_prompt, vocab, None,
    )
}

/// [`build_zipf_trace`] with an optional shared-prefix pool: `Some(pool)`
/// makes each request reuse its adapter's system prefix per the pool's
/// reuse probability; `None` is bit-identical to the plain Zipf trace (the
/// prompt formula consumes no rng draws, so the arrival/length/adapter
/// sequences cannot shift).
#[allow(clippy::too_many_arguments)]
pub fn build_zipf_trace_shared(
    seed: u64,
    n: usize,
    n_adapters: usize,
    zipf_s: f64,
    arrivals: &mut dyn ArrivalProcess,
    lengths: &LengthModel,
    max_new: usize,
    max_prompt: usize,
    vocab: i32,
    prefixes: Option<&PrefixPool>,
) -> Trace {
    let zipf = ZipfAdapters::new(n_adapters, zipf_s);
    let mut rng = Rng::seed_from_u64(seed);
    let mut requests = Vec::with_capacity(n);
    for i in 0..n {
        let arrival_s = arrivals.next_arrival(&mut rng);
        let adapter = zipf.sample(&mut rng);
        let len = lengths.sample_prompt(&mut rng).clamp(1, max_prompt);
        let prompt: Vec<i32> = match prefixes {
            Some(pool) => pool.prompt(&mut rng, adapter, len, i, vocab),
            None => (0..len).map(|k| ((i * 131 + k * 7 + 3) as i32) % vocab).collect(),
        };
        requests.push(InferenceRequest {
            id: i as u64,
            adapter,
            prompt,
            max_new_tokens: max_new,
            eos_token: None,
            arrival_s,
            slo: None,
        });
    }
    requests.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    Trace { requests }
}

/// Build a fine-tuning dataset with the given length model (Alpaca/GSM8K
/// stand-ins: token ids are synthetic, lengths match the dataset).
pub fn build_train_set(
    seed: u64,
    n: usize,
    lengths: &LengthModel,
    max_len: usize,
    vocab: i32,
) -> Vec<TrainExample> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n)
        .map(|i| {
            let len = lengths.sample_prompt(&mut rng).clamp(4, max_len);
            let tokens: Vec<i32> =
                (0..len).map(|k| ((i * 97 + k * 13 + 5) as i32) % vocab).collect();
            let labels = tokens.clone();
            TrainExample { tokens, labels }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_sampler_is_deterministic_head_heavy_and_in_range() {
        let n_adapters = 1000;
        let zipf = ZipfAdapters::new(n_adapters, 1.0);
        let mut rng = Rng::seed_from_u64(42);
        let mut counts = vec![0usize; n_adapters];
        for _ in 0..20_000 {
            let a = zipf.sample(&mut rng);
            assert!((0..n_adapters as i32).contains(&a));
            counts[a as usize] += 1;
        }
        // Rank 0 dominates rank 99 by roughly the 1/rank law (factor 100
        // in expectation; demand only a loose factor to stay robust).
        assert!(counts[0] > counts[99] * 10, "head {} vs rank-99 {}", counts[0], counts[99]);
        // The tail is actually exercised: many distinct adapters appear.
        let distinct = counts.iter().filter(|&&c| c > 0).count();
        assert!(distinct > 100, "only {distinct} distinct adapters drawn");
        // Same seed reproduces the same draw sequence.
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(zipf.sample(&mut a), zipf.sample(&mut b));
        }
    }

    #[test]
    fn tenant_trace_shares_per_adapter_prefixes() {
        let mut arrivals = PoissonArrivals::new(4.0);
        let lengths = LengthModel { mu: 3.0, sigma: 0.2, min: 16, max: 32 };
        let t = build_tenant_trace(5, 200, 4, &mut arrivals, &lengths, 8, 0.9, 4, 64, 97);
        assert_eq!(t.requests.len(), 200);
        let pool = PrefixPool::new(5 ^ 0x5eed_cafe, 4, 8, 0.9, 97);
        // Most requests carry their adapter's fixed 8-token system prefix;
        // distinct adapters carry distinct prefixes.
        let hits = t
            .requests
            .iter()
            .filter(|r| r.prompt.len() >= 8 && r.prompt[..8] == *pool.prefix(r.adapter))
            .count();
        assert!(hits > 150, "only {hits}/200 requests reuse their prefix");
        assert_ne!(pool.prefix(0), pool.prefix(1));
        // Reproducible: same seed, same trace.
        let mut arrivals2 = PoissonArrivals::new(4.0);
        let t2 = build_tenant_trace(5, 200, 4, &mut arrivals2, &lengths, 8, 0.9, 4, 64, 97);
        for (a, b) in t.requests.iter().zip(&t2.requests) {
            assert_eq!(a.prompt, b.prompt);
        }
    }

    #[test]
    fn zipf_trace_without_pool_is_bit_identical_to_plain() {
        // The `None` wrapper must not perturb the gated Zipf figures: the
        // rng draw sequence and every prompt token stay exactly as before.
        let lengths = LengthModel { mu: 2.0, sigma: 0.2, min: 4, max: 16 };
        let mut a1 = PoissonArrivals::new(4.0);
        let t1 = build_zipf_trace(3, 100, 50, 1.0, &mut a1, &lengths, 4, 32, 97);
        let mut a2 = PoissonArrivals::new(4.0);
        let t2 =
            build_zipf_trace_shared(3, 100, 50, 1.0, &mut a2, &lengths, 4, 32, 97, None);
        for (a, b) in t1.requests.iter().zip(&t2.requests) {
            assert_eq!((a.id, a.adapter, a.arrival_s), (b.id, b.adapter, b.arrival_s));
            assert_eq!(a.prompt, b.prompt);
        }
        // With a pool, hot adapters' requests share content.
        let pool = PrefixPool::new(9, 50, 6, 1.0, 97);
        let mut a3 = PoissonArrivals::new(4.0);
        let t3 = build_zipf_trace_shared(3, 100, 50, 1.0, &mut a3, &lengths, 4, 32, 97, Some(&pool));
        let shared = t3
            .requests
            .iter()
            .filter(|r| r.prompt.len() >= 6 && r.prompt[..6] == *pool.prefix(r.adapter))
            .count();
        assert!(shared > 60, "only {shared}/100 zipf requests reuse prefixes");
    }

    #[test]
    fn zipf_trace_spans_many_adapters_and_sorts_arrivals() {
        let mut arrivals = PoissonArrivals::new(4.0);
        let lengths = LengthModel { mu: 2.0, sigma: 0.2, min: 4, max: 16 };
        let t = build_zipf_trace(3, 500, 200, 1.0, &mut arrivals, &lengths, 4, 32, 97);
        assert_eq!(t.requests.len(), 500);
        let mut adapters: Vec<i32> = t.requests.iter().map(|r| r.adapter).collect();
        adapters.sort_unstable();
        adapters.dedup();
        assert!(adapters.len() > 20, "zipf trace should touch many adapters");
        assert!(adapters.iter().all(|&a| (0..200).contains(&a)));
        for w in t.requests.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
    }
}
