//! Workload generation: request length models and arrival processes for
//! every experiment in the paper (DESIGN.md §3 records the dataset
//! substitutions — the figures depend on length/arrival *distributions*,
//! which we reproduce from each dataset's published statistics).

mod arrivals;
mod burstgpt;
mod lengths;

pub use arrivals::{table7_schedule, ArrivalProcess, MutablePhase, PoissonArrivals, ScheduleArrivals};
pub use burstgpt::{trace_stats, BurstGptSlice, BurstGptSynth, TABLE8_SLICES};
pub use lengths::{LengthModel, ALPACA_LENGTHS, GSM8K_LENGTHS, SHAREGPT_LENGTHS};

use crate::coordinator::{InferenceRequest, TrainExample};
use crate::util::rng::Rng;

/// A fully materialized inference trace (arrival-sorted).
#[derive(Debug, Clone)]
pub struct Trace {
    pub requests: Vec<InferenceRequest>,
}

impl Trace {
    pub fn duration_s(&self) -> f64 {
        self.requests.last().map(|r| r.arrival_s).unwrap_or(0.0)
    }
}

/// Build an inference trace: `n` requests across `adapters`, arrivals from
/// `arrivals`, prompt lengths from `lengths`, fixed `max_new` (the paper's
/// Appendix D.2/D.4 tables fix max-new per RPS row).
#[allow(clippy::too_many_arguments)]
pub fn build_trace(
    seed: u64,
    n: usize,
    adapters: &[i32],
    arrivals: &mut dyn ArrivalProcess,
    lengths: &LengthModel,
    max_new: usize,
    max_prompt: usize,
    vocab: i32,
) -> Trace {
    let mut rng = Rng::seed_from_u64(seed);
    let mut requests = Vec::with_capacity(n);
    for i in 0..n {
        let arrival_s = arrivals.next_arrival(&mut rng);
        let len = lengths.sample_prompt(&mut rng).clamp(1, max_prompt);
        let prompt: Vec<i32> = (0..len).map(|k| ((i * 131 + k * 7 + 3) as i32) % vocab).collect();
        requests.push(InferenceRequest {
            id: i as u64,
            adapter: adapters[i % adapters.len()],
            prompt,
            max_new_tokens: max_new,
            eos_token: None,
            arrival_s,
            slo: None,
        });
    }
    requests.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    Trace { requests }
}

/// Build a fine-tuning dataset with the given length model (Alpaca/GSM8K
/// stand-ins: token ids are synthetic, lengths match the dataset).
pub fn build_train_set(
    seed: u64,
    n: usize,
    lengths: &LengthModel,
    max_len: usize,
    vocab: i32,
) -> Vec<TrainExample> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n)
        .map(|i| {
            let len = lengths.sample_prompt(&mut rng).clamp(4, max_len);
            let tokens: Vec<i32> =
                (0..len).map(|k| ((i * 97 + k * 13 + 5) as i32) % vocab).collect();
            let labels = tokens.clone();
            TrainExample { tokens, labels }
        })
        .collect()
}
