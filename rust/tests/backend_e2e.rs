//! End-to-end over the REAL XLA backend: serve + fine-tune through the
//! coordinator with actual PJRT execution (tiny workload — numerics, cache
//! continuity and trainer plumbing, not throughput).

use std::path::PathBuf;

use loquetier::coordinator::{
    Coordinator, CoordinatorConfig, FinetuneJob, InferenceRequest, TrainExample,
};
use loquetier::engine::{Backend, DecodeRow, PrefillSeq, TrainSeq, XlaBackend};
use loquetier::kvcache::{CacheConfig, KvCacheManager};
use loquetier::model::{LoraAdapter, SlotState, VirtualizedRegistry, WeightStore};
use loquetier::runtime::Runtime;

// PJRT CPU clients race on TFRT runtime singletons when created
// concurrently from multiple test threads — serialize every test.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

/// None = artifacts absent: skip (the offline environment cannot run
/// `make artifacts`; see DESIGN.md §3).
fn artifacts_dir() -> Option<PathBuf> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let dir = root.join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing — run `make artifacts` first");
        return None;
    }
    Some(dir)
}

/// Compile only the entries a test needs — full compilation is ~90 s and
/// dominates test wall time otherwise.
fn make_backend_filtered(
    filter: impl Fn(&str) -> bool,
) -> Option<(XlaBackend, VirtualizedRegistry)> {
    let dir = artifacts_dir()?;
    let rt = Runtime::load_filtered(&dir, filter).expect("runtime");
    let manifest = rt.manifest.clone();
    let store = WeightStore::open(&dir, &manifest).unwrap();
    let mut reg = VirtualizedRegistry::new(&manifest, &store).unwrap();
    for i in 0..manifest.build.lora.max_adapters {
        let ad = LoraAdapter::from_store(&store, &manifest, i, format!("a{i}")).unwrap();
        reg.attach(format!("vm{i}"), ad, i, SlotState::Inference).unwrap();
    }
    let mut be = XlaBackend::new(rt, &store).unwrap();
    be.sync_adapters(&mut reg).unwrap();
    Some((be, reg))
}

#[allow(dead_code)]
fn make_backend() -> Option<(XlaBackend, VirtualizedRegistry)> {
    make_backend_filtered(|_| true)
}

fn make_cache(be: &XlaBackend) -> KvCacheManager {
    let g = be.geometry().clone();
    KvCacheManager::new(CacheConfig {
        num_slots: 16,
        slot_capacity: g.max_cache_len,
        block_tokens: 16,
        total_blocks: 16 * g.max_cache_len / 16,
        num_layers: g.num_layers,
        token_elems: g.num_kv_heads * g.head_dim,
    })
}

#[test]
fn decode_continuation_matches_full_prefill() {
    let _guard = serial();
    // prefill(t0..t12) then decode(t13) == prefill(t0..t13) last logits.
    let Some((mut be, _reg)) = make_backend_filtered(|n| n == "prefill_b1_s16" || n == "decode_b1")
    else {
        return;
    };
    let mut cache = make_cache(&be);
    let toks: Vec<i32> = (0..13).map(|i| (7 * i + 3) % 512).collect();

    let slot_a = cache.allocate(1, 64).unwrap();
    let (full, _) = be
        .prefill(
            &[PrefillSeq { tokens: toks.clone(), adapter: 2, kv_slot: slot_a }],
            &mut cache,
        )
        .unwrap();

    let slot_b = cache.allocate(2, 64).unwrap();
    let (_, _) = be
        .prefill(
            &[PrefillSeq { tokens: toks[..12].to_vec(), adapter: 2, kv_slot: slot_b }],
            &mut cache,
        )
        .unwrap();
    let (dec, _) = be
        .decode(&[DecodeRow { token: toks[12], adapter: 2, kv_slot: slot_b }], &mut cache)
        .unwrap();

    let mut worst = 0.0f32;
    for (a, b) in full[0].iter().zip(&dec[0]) {
        worst = worst.max((a - b).abs() / b.abs().max(1.0));
    }
    assert!(worst < 5e-3, "decode continuation diverged: rel err {worst}");
    assert_eq!(cache.len(slot_b), 13);
}

#[test]
fn adapters_route_to_different_logits() {
    let _guard = serial();
    let Some((mut be, _reg)) = make_backend_filtered(|n| n == "prefill_b4_s16") else {
        return;
    };
    let mut cache = make_cache(&be);
    let toks: Vec<i32> = (0..16).map(|i| (11 * i + 5) % 512).collect();
    let s0 = cache.allocate(1, 32).unwrap();
    let s1 = cache.allocate(2, 32).unwrap();
    let s2 = cache.allocate(3, 32).unwrap();
    // Same prompt through adapter 0, adapter 1, and the bare base model —
    // in ONE batched launch (the SMLM multi-adapter path).
    let (logits, _) = be
        .prefill(
            &[
                PrefillSeq { tokens: toks.clone(), adapter: 0, kv_slot: s0 },
                PrefillSeq { tokens: toks.clone(), adapter: 1, kv_slot: s1 },
                PrefillSeq { tokens: toks.clone(), adapter: -1, kv_slot: s2 },
            ],
            &mut cache,
        )
        .unwrap();
    let d01: f32 = logits[0].iter().zip(&logits[1]).map(|(a, b)| (a - b).abs()).sum();
    let d0b: f32 = logits[0].iter().zip(&logits[2]).map(|(a, b)| (a - b).abs()).sum();
    assert!(d01 > 1e-3, "adapters 0 and 1 must differ");
    assert!(d0b > 1e-3, "adapter 0 must differ from base");
    assert!(logits.iter().all(|l| l.iter().all(|x| x.is_finite())));
}

#[test]
fn training_reduces_loss_on_repeated_batch() {
    let _guard = serial();
    let Some((mut be, _reg)) = make_backend_filtered(|n| n == "train_b1_s64" || n == "adam")
    else {
        return;
    };
    let seq: Vec<i32> = (0..48).map(|i| (5 * i + 1) % 512).collect();
    let mk = || TrainSeq {
        tokens: seq.clone(),
        labels: seq.clone(),
        adapter: 0,
        train: true,
        loss_scale: 1.0,
    };
    let mut first = None;
    let mut last = 0.0;
    for step in 1..=6 {
        let (losses, _) = be.train_step(&[mk()]).unwrap();
        if first.is_none() {
            first = Some(losses[0]);
        }
        last = losses[0];
        be.optim_step(&[0], 5e-2, step).unwrap();
    }
    let first = first.unwrap();
    assert!(
        last < first - 0.05,
        "loss must descend on a repeated batch: {first} -> {last}"
    );
}

#[test]
fn unified_step_runs_all_three_classes() {
    let _guard = serial();
    let Some((mut be, _reg)) = make_backend_filtered(|n| {
        n == "unified_0" || n == "prefill_b1_s16" || n == "decode_b1"
    }) else {
        return;
    };
    let mut cache = make_cache(&be);
    let ft = TrainSeq {
        tokens: (0..32).map(|i| (3 * i + 2) % 512).collect(),
        labels: (0..32).map(|i| (3 * i + 2) % 512).collect(),
        adapter: 3,
        train: true,
        loss_scale: 0.25,
    };
    let pf_slot = cache.allocate(10, 64).unwrap();
    let pf = PrefillSeq {
        tokens: (0..16).map(|i| (9 * i + 4) % 512).collect(),
        adapter: 1,
        kv_slot: pf_slot,
    };
    let dec_slot = cache.allocate(11, 32).unwrap();
    // Seed the decode slot with a short prefill.
    be.prefill(
        &[PrefillSeq { tokens: vec![17, 23, 31], adapter: 0, kv_slot: dec_slot }],
        &mut cache,
    )
    .unwrap();
    let dec = DecodeRow { token: 42, adapter: 0, kv_slot: dec_slot };

    let (out, _cost) = be.unified(&[ft], &[pf], &[dec.clone()], &mut cache).unwrap();
    assert_eq!(out.ft_losses.len(), 1);
    assert!(out.ft_losses[0].is_finite() && out.ft_losses[0] > 0.0);
    assert_eq!(out.pf_last_logits.len(), 1);
    assert_eq!(out.dec_logits.len(), 1);
    assert!(out.dec_logits[0].iter().all(|x| x.is_finite()));
    assert_eq!(cache.len(pf_slot), 16, "prefill KV must land in the slot");
    assert_eq!(cache.len(dec_slot), 4, "decode KV must append");

    // The decode row must match what a dedicated decode launch produces
    // (unified batching is a scheduling optimization, not a semantics
    // change — the paper's core claim).
    let mut cache2 = make_cache(&be);
    let dec_slot2 = cache2.allocate(12, 32).unwrap();
    be.prefill(
        &[PrefillSeq { tokens: vec![17, 23, 31], adapter: 0, kv_slot: dec_slot2 }],
        &mut cache2,
    )
    .unwrap();
    let (alone, _) = be
        .decode(&[DecodeRow { token: 42, adapter: 0, kv_slot: dec_slot2 }], &mut cache2)
        .unwrap();
    let mut worst = 0.0f32;
    for (a, b) in out.dec_logits[0].iter().zip(&alone[0]) {
        worst = worst.max((a - b).abs() / b.abs().max(1.0));
    }
    assert!(worst < 5e-3, "unified decode != dedicated decode: rel {worst}");
}

#[test]
fn full_coordinator_serves_on_xla_backend() {
    let _guard = serial();
    // The real serving loop end-to-end at tiny scale: 6 requests across 3
    // adapters + one fine-tune job, through the unified coordinator.
    let Some((mut be, _reg)) = make_backend_filtered(|n| {
        n == "unified_0" || n.starts_with("prefill") || n.starts_with("decode") || n == "adam"
    }) else {
        return;
    };
    let g = be.geometry().clone();
    let mut coord = Coordinator::new(
        CoordinatorConfig { max_prompt_tokens: 16, ..Default::default() },
        CacheConfig {
            num_slots: 8,
            slot_capacity: g.max_cache_len,
            block_tokens: 16,
            total_blocks: 8 * g.max_cache_len / 16,
            num_layers: g.num_layers,
            token_elems: g.num_kv_heads * g.head_dim,
        },
    );
    for i in 0..6u64 {
        coord.submit(InferenceRequest {
            id: i,
            adapter: (i % 3) as i32,
            prompt: (0..8).map(|k| ((i as i32) * 31 + k * 7 + 3) % 512).collect(),
            max_new_tokens: 4,
            eos_token: None,
            arrival_s: 0.0,
        });
    }
    let ex = |i: usize| TrainExample {
        tokens: (0..24).map(|k| ((i * 13 + k * 3 + 1) as i32) % 512).collect(),
        labels: (0..24).map(|k| ((i * 13 + k * 3 + 1) as i32) % 512).collect(),
    };
    coord.add_trainer(FinetuneJob {
        id: 1,
        adapter: 3,
        train_set: (0..4).map(ex).collect(),
        eval_set: (0..1).map(ex).collect(),
        epochs: 1,
        per_device_batch: 2,
        grad_accum: 2,
        lr: 2e-5,
        eval_each_epoch: true,
    });

    let mut steps = 0;
    while !coord.quiescent() && steps < 200 {
        let out = coord.step(&mut be).unwrap();
        if out.idle {
            break;
        }
        steps += 1;
    }
    assert!(coord.quiescent(), "work must drain (steps={steps})");
    assert_eq!(coord.traces.len(), 6);
    assert!(coord.traces.iter().all(|t| !t.failed && t.output_tokens == 4));
    assert_eq!(coord.finetune_tokens(), 4 * 24);
    assert_eq!(coord.eval_tokens(), 24);
    assert!(coord.trainers()[0].done());
    assert_eq!(coord.kv.stats().slots_used, 0, "all KV slots recycled");
}
