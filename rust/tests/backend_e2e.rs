//! End-to-end over REAL numerics: serve + fine-tune through the
//! coordinator with actual forward/backward math (tiny workload —
//! numerics, cache continuity and trainer plumbing, not throughput).
//!
//! Every scenario is generic over [`Backend`] and runs twice:
//!
//! * **native** — the pure-Rust CPU backend over a seeded random-weight
//!   tiny model. No artifacts, no PJRT, NO SKIPS: this is what tier-1 CI
//!   exercises.
//! * **xla** — the AOT-artifact path, skip-on-absent as before (the
//!   offline environment cannot run `make artifacts`; DESIGN.md §3 S7).

use std::path::PathBuf;

use loquetier::coordinator::{
    Coordinator, CoordinatorConfig, FinetuneJob, InferenceRequest, TrainExample,
};
use loquetier::engine::{Backend, DecodeRow, PrefillSeq, TrainSeq, XlaBackend};
use loquetier::harness::{xla_stack, HarnessBuilder};
use loquetier::kvcache::KvCacheManager;
use loquetier::model::VirtualizedRegistry;

// PJRT CPU clients race on TFRT runtime singletons when created
// concurrently from multiple test threads — serialize the XLA tests.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

/// None = artifacts absent: skip the XLA variant only (the native variant
/// of every scenario runs unconditionally).
fn artifacts_dir() -> Option<PathBuf> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let dir = root.join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing — run `make artifacts` first");
        return None;
    }
    Some(dir)
}

/// Compile only the entries a test needs — full compilation is ~90 s and
/// dominates test wall time otherwise.
fn make_xla_filtered(
    filter: impl Fn(&str) -> bool,
) -> Option<(XlaBackend, VirtualizedRegistry)> {
    let dir = artifacts_dir()?;
    let (be, reg, _manifest, _store) = xla_stack(&dir, filter).expect("xla stack");
    Some((be, reg))
}

fn make_cache(be: &dyn Backend) -> KvCacheManager {
    KvCacheManager::new(loquetier::harness::cache_config_for(be.geometry(), 16))
}

// ---------------------------------------------------------------------------
// Scenarios (backend-generic)
// ---------------------------------------------------------------------------

/// prefill(t0..t12) then decode(t13) must equal prefill(t0..t13) last
/// logits — KV continuity across the arena.
fn scenario_decode_continuation(be: &mut dyn Backend, rtol: f32) {
    let v = be.geometry().vocab_size as i32;
    let mut cache = make_cache(be);
    let toks: Vec<i32> = (0..13).map(|i| (7 * i + 3) % v).collect();

    let slot_a = cache.allocate(1, 64).unwrap();
    let (full, _) = be
        .prefill(
            &[PrefillSeq { tokens: toks.clone(), adapter: 2, kv_slot: slot_a }],
            &mut cache,
        )
        .unwrap();

    let slot_b = cache.allocate(2, 64).unwrap();
    let (_, _) = be
        .prefill(
            &[PrefillSeq { tokens: toks[..12].to_vec(), adapter: 2, kv_slot: slot_b }],
            &mut cache,
        )
        .unwrap();
    let (dec, _) = be
        .decode(&[DecodeRow { token: toks[12], adapter: 2, kv_slot: slot_b }], &mut cache)
        .unwrap();

    let mut worst = 0.0f32;
    for (a, b) in full[0].iter().zip(&dec[0]) {
        worst = worst.max((a - b).abs() / b.abs().max(1.0));
    }
    assert!(worst < rtol, "decode continuation diverged: rel err {worst}");
    assert_eq!(cache.len(slot_b), 13);
}

/// Same prompt through two adapters and the bare base — in ONE batched
/// launch (the SMLM multi-adapter path) — must route to distinct logits.
fn scenario_adapter_routing(be: &mut dyn Backend) {
    let v = be.geometry().vocab_size as i32;
    let mut cache = make_cache(be);
    let toks: Vec<i32> = (0..16).map(|i| (11 * i + 5) % v).collect();
    let s0 = cache.allocate(1, 32).unwrap();
    let s1 = cache.allocate(2, 32).unwrap();
    let s2 = cache.allocate(3, 32).unwrap();
    let (logits, _) = be
        .prefill(
            &[
                PrefillSeq { tokens: toks.clone(), adapter: 0, kv_slot: s0 },
                PrefillSeq { tokens: toks.clone(), adapter: 1, kv_slot: s1 },
                PrefillSeq { tokens: toks.clone(), adapter: -1, kv_slot: s2 },
            ],
            &mut cache,
        )
        .unwrap();
    let d01: f32 = logits[0].iter().zip(&logits[1]).map(|(a, b)| (a - b).abs()).sum();
    let d0b: f32 = logits[0].iter().zip(&logits[2]).map(|(a, b)| (a - b).abs()).sum();
    assert!(d01 > 1e-3, "adapters 0 and 1 must differ");
    assert!(d0b > 1e-3, "adapter 0 must differ from base");
    assert!(logits.iter().all(|l| l.iter().all(|x| x.is_finite())));
}

/// Train on a repeated batch: loss must descend (real gradients + Adam).
fn scenario_training_descends(be: &mut dyn Backend, lr: f32, steps: i32) {
    let v = be.geometry().vocab_size as i32;
    let seq: Vec<i32> = (0..48).map(|i| (5 * i + 1) % v).collect();
    let mk = || TrainSeq {
        tokens: seq.clone(),
        labels: seq.clone(),
        adapter: 0,
        train: true,
        loss_scale: 1.0,
    };
    let mut first = None;
    let mut last = 0.0;
    for step in 1..=steps {
        let (losses, _) = be.train_step(&[mk()]).unwrap();
        if first.is_none() {
            first = Some(losses[0]);
        }
        last = losses[0];
        be.optim_step(&[0], lr, step).unwrap();
    }
    let first = first.unwrap();
    assert!(
        last < first - 0.05,
        "loss must descend on a repeated batch: {first} -> {last}"
    );
}

/// The unified launch runs fine-tune ∥ prefill ∥ decode and its decode
/// rows match a dedicated decode launch — batching is a scheduling
/// optimization, not a semantics change (the paper's core claim).
fn scenario_unified_all_classes(be: &mut dyn Backend, rtol: f32) {
    let v = be.geometry().vocab_size as i32;
    let mut cache = make_cache(be);
    let ft = TrainSeq {
        tokens: (0..32).map(|i| (3 * i + 2) % v).collect(),
        labels: (0..32).map(|i| (3 * i + 2) % v).collect(),
        adapter: 3,
        train: true,
        loss_scale: 0.25,
    };
    let pf_slot = cache.allocate(10, 64).unwrap();
    let pf = PrefillSeq {
        tokens: (0..16).map(|i| (9 * i + 4) % v).collect(),
        adapter: 1,
        kv_slot: pf_slot,
    };
    let dec_slot = cache.allocate(11, 32).unwrap();
    be.prefill(
        &[PrefillSeq { tokens: vec![17 % v, 23 % v, 31 % v], adapter: 0, kv_slot: dec_slot }],
        &mut cache,
    )
    .unwrap();
    let dec = DecodeRow { token: 42 % v, adapter: 0, kv_slot: dec_slot };

    let (out, _cost) = be.unified(&[ft], &[pf], &[dec.clone()], &mut cache).unwrap();
    assert_eq!(out.ft_losses.len(), 1);
    assert!(out.ft_losses[0].is_finite() && out.ft_losses[0] > 0.0);
    assert_eq!(out.pf_last_logits.len(), 1);
    assert_eq!(out.dec_logits.len(), 1);
    assert!(out.dec_logits[0].iter().all(|x| x.is_finite()));
    assert_eq!(cache.len(pf_slot), 16, "prefill KV must land in the slot");
    assert_eq!(cache.len(dec_slot), 4, "decode KV must append");

    let mut cache2 = make_cache(be);
    let dec_slot2 = cache2.allocate(12, 32).unwrap();
    be.prefill(
        &[PrefillSeq { tokens: vec![17 % v, 23 % v, 31 % v], adapter: 0, kv_slot: dec_slot2 }],
        &mut cache2,
    )
    .unwrap();
    let (alone, _) = be
        .decode(&[DecodeRow { token: 42 % v, adapter: 0, kv_slot: dec_slot2 }], &mut cache2)
        .unwrap();
    let mut worst = 0.0f32;
    for (a, b) in out.dec_logits[0].iter().zip(&alone[0]) {
        worst = worst.max((a - b).abs() / b.abs().max(1.0));
    }
    assert!(worst < rtol, "unified decode != dedicated decode: rel {worst}");
}

/// The real serving loop end-to-end at tiny scale: 6 requests across 3
/// adapters + one fine-tune job, through the unified coordinator.
fn scenario_full_coordinator(be: &mut dyn Backend) {
    let g = be.geometry().clone();
    let v = g.vocab_size as i32;
    let mut coord = Coordinator::new(
        CoordinatorConfig { max_prompt_tokens: 16, ..Default::default() },
        loquetier::harness::cache_config_for(&g, 8),
    );
    for i in 0..6u64 {
        coord.submit(InferenceRequest {
            id: i,
            adapter: (i % 3) as i32,
            prompt: (0..8).map(|k| ((i as i32) * 31 + k * 7 + 3) % v).collect(),
            max_new_tokens: 4,
            eos_token: None,
            arrival_s: 0.0,
            slo: None,
        });
    }
    let ex = |i: usize| TrainExample {
        tokens: (0..24).map(|k| ((i * 13 + k * 3 + 1) as i32) % v).collect(),
        labels: (0..24).map(|k| ((i * 13 + k * 3 + 1) as i32) % v).collect(),
    };
    coord.add_trainer(FinetuneJob {
        id: 1,
        adapter: 3,
        train_set: (0..4).map(ex).collect(),
        eval_set: (0..1).map(ex).collect(),
        epochs: 1,
        per_device_batch: 2,
        grad_accum: 2,
        lr: 2e-5,
        eval_each_epoch: true,
    });

    let mut steps = 0;
    while !coord.quiescent() && steps < 200 {
        let out = coord.step(be).unwrap();
        if out.idle {
            break;
        }
        steps += 1;
    }
    assert!(coord.quiescent(), "work must drain (steps={steps})");
    assert_eq!(coord.traces.len(), 6);
    assert!(coord.traces.iter().all(|t| !t.failed && t.output_tokens == 4));
    assert_eq!(coord.finetune_tokens(), 4 * 24);
    assert_eq!(coord.eval_tokens(), 24);
    assert!(coord.trainers()[0].done());
    assert_eq!(coord.kv.stats().slots_used, 0, "all KV slots recycled");
}

// ---------------------------------------------------------------------------
// Native backend: unconditional (zero artifacts, zero skips)
// ---------------------------------------------------------------------------

#[test]
fn native_decode_continuation_matches_full_prefill() {
    let (mut be, _reg, _m) = HarnessBuilder::new().seed(42).native_stack().unwrap();
    // Identical code path + fixed accumulation order ⇒ tight tolerance.
    scenario_decode_continuation(&mut be, 1e-5);
}

#[test]
fn native_adapters_route_to_different_logits() {
    let (mut be, _reg, _m) = HarnessBuilder::new().seed(42).native_stack().unwrap();
    scenario_adapter_routing(&mut be);
}

#[test]
fn native_training_reduces_loss_on_repeated_batch() {
    let (mut be, _reg, _m) = HarnessBuilder::new().seed(42).native_stack().unwrap();
    scenario_training_descends(&mut be, 2e-2, 8);
}

#[test]
fn native_unified_step_runs_all_three_classes() {
    let (mut be, _reg, _m) = HarnessBuilder::new().seed(42).native_stack().unwrap();
    scenario_unified_all_classes(&mut be, 1e-5);
}

#[test]
fn native_full_coordinator_serves() {
    let (mut be, _reg, _m) = HarnessBuilder::new().seed(42).native_stack().unwrap();
    scenario_full_coordinator(&mut be);
}

#[test]
fn native_checkpoint_roundtrips_trained_adapter() {
    // Train, checkpoint into the registry, extract, re-attach on a fresh
    // stack: the trained delta must survive the save path.
    let (mut be, mut reg, _m) = HarnessBuilder::new().seed(42).native_stack().unwrap();
    let v = be.geometry().vocab_size as i32;
    let seq: Vec<i32> = (0..24).map(|i| (5 * i + 2) % v).collect();
    for step in 1..=3 {
        be.train_step(&[TrainSeq {
            tokens: seq.clone(),
            labels: seq.clone(),
            adapter: 1,
            train: true,
            loss_scale: 1.0,
        }])
        .unwrap();
        be.optim_step(&[1], 1e-2, step).unwrap();
    }
    be.checkpoint_adapters(&mut reg).unwrap();
    let trained = reg.extract(1).unwrap();
    let original = reg.extract(0).unwrap();
    // The trained slot moved; an untrained slot did not.
    let (_be2, reg2, _m2) = HarnessBuilder::new().seed(42).native_stack().unwrap();
    let fresh = reg2.extract(1).unwrap();
    let delta: f32 = trained
        .modules
        .values()
        .zip(fresh.modules.values())
        .map(|(a, b)| a.a.iter().zip(&b.a).map(|(x, y)| (x - y).abs()).sum::<f32>())
        .sum();
    assert!(delta > 1e-4, "training must change the checkpointed adapter");
    let fresh0 = reg2.extract(0).unwrap();
    let delta0: f32 = original
        .modules
        .values()
        .zip(fresh0.modules.values())
        .map(|(a, b)| a.a.iter().zip(&b.a).map(|(x, y)| (x - y).abs()).sum::<f32>())
        .sum();
    assert_eq!(delta0, 0.0, "untrained slots stay bit-identical");
}

// ---------------------------------------------------------------------------
// XLA backend: artifact-gated (skip-on-absent, unchanged behaviour)
// ---------------------------------------------------------------------------

#[test]
fn xla_decode_continuation_matches_full_prefill() {
    let _guard = serial();
    let Some((mut be, _reg)) = make_xla_filtered(|n| n == "prefill_b1_s16" || n == "decode_b1")
    else {
        return;
    };
    scenario_decode_continuation(&mut be, 5e-3);
}

#[test]
fn xla_adapters_route_to_different_logits() {
    let _guard = serial();
    let Some((mut be, _reg)) = make_xla_filtered(|n| n == "prefill_b4_s16") else {
        return;
    };
    scenario_adapter_routing(&mut be);
}

#[test]
fn xla_training_reduces_loss_on_repeated_batch() {
    let _guard = serial();
    let Some((mut be, _reg)) = make_xla_filtered(|n| n == "train_b1_s64" || n == "adam") else {
        return;
    };
    scenario_training_descends(&mut be, 5e-2, 6);
}

#[test]
fn xla_unified_step_runs_all_three_classes() {
    let _guard = serial();
    let Some((mut be, _reg)) = make_xla_filtered(|n| {
        n == "unified_0" || n == "prefill_b1_s16" || n == "decode_b1"
    }) else {
        return;
    };
    scenario_unified_all_classes(&mut be, 5e-3);
}

#[test]
fn xla_full_coordinator_serves() {
    let _guard = serial();
    let Some((mut be, _reg)) = make_xla_filtered(|n| {
        n == "unified_0" || n.starts_with("prefill") || n.starts_with("decode") || n == "adam"
    }) else {
        return;
    };
    scenario_full_coordinator(&mut be);
}
