//! Seeded chaos suite (DESIGN.md §12): drives the REAL coordinator over a
//! fault-injecting backend and checks the paper-level robustness contract:
//!
//! * the supervised step loop never dies — transient errors, injected
//!   panics and latency spikes are retried/absorbed, a poisoned request is
//!   isolated and quarantined while every other stream keeps running;
//! * streams untouched by faults are BITWISE identical to a fault-free
//!   run (batch-composition invariance of the native kernels makes the
//!   retry/isolate path invisible in the numbers);
//! * the KV block ledger audits clean after every recovery;
//! * a trainer restored from a durable crash-safe checkpoint continues
//!   its loss sequence bit-identically;
//! * the JSON-lines engine loop survives a probabilistic fault storm and
//!   surfaces the supervision counters in the stats frame.
//!
//! CI greps the `CHAOS_STATS` / `CHAOS_CKPT` / `CHAOS_FRAME` lines printed
//! here and jq-gates the counters (see .github/workflows/ci.yml).

use std::collections::BTreeMap;
use std::sync::mpsc::channel;
use std::time::Duration;

use loquetier::coordinator::{
    Coordinator, CoordinatorConfig, FinetuneJob, InferenceRequest, TrainExample,
};
use loquetier::engine::{Backend, CostModel, FaultKind, FaultPlan, FaultyBackend};
use loquetier::harness::{sim_backend, sim_cache_config, HarnessBuilder};
use loquetier::kvcache::CacheConfig;
use loquetier::model::AdapterCheckpoint;
use loquetier::server::{
    engine_loop, AdmissionConfig, EngineMsg, ErrCode, Frontend, GenerateJob, StaticDirectory,
    TokenEvent,
};

/// Content-keyed poison marker: never a real token (generated tokens are
/// argmax indices >= 0), so it can only appear where a test plants it —
/// and the injector faults it BEFORE the kernels would ever index with it.
const POISON: i32 = -13;

fn native_cache() -> CacheConfig {
    // Native-stack geometry (2 layers, token_elems 16); generous block
    // pool so preemption never perturbs the parity comparison.
    CacheConfig {
        num_slots: 8,
        slot_capacity: 160,
        block_tokens: 16,
        total_blocks: 64,
        num_layers: 2,
        token_elems: 16,
    }
}

fn chaos_cfg() -> CoordinatorConfig {
    CoordinatorConfig { max_prompt_tokens: 16, drop_after_s: 1e9, ..Default::default() }
}

fn train_job() -> FinetuneJob {
    let ex = |i: usize| TrainExample {
        tokens: (0..12).map(|k| ((i * 13 + k * 5 + 1) % 509) as i32).collect(),
        labels: (0..12).map(|k| ((i * 13 + k * 5 + 1) % 509) as i32).collect(),
    };
    FinetuneJob {
        id: 100,
        // Slot 3 is training-only in this workload: inference uses -1..2,
        // so quarantine-induced scheduling shifts cannot couple into the
        // served outputs through adapter state.
        adapter: 3,
        train_set: (0..6).map(ex).collect(),
        eval_set: vec![],
        epochs: 1,
        per_device_batch: 1,
        grad_accum: 2,
        lr: 1e-3,
        eval_each_epoch: false,
    }
}

/// Submit the mixed ft∥pf∥dec workload and drive it to quiescence,
/// auditing the ledger after every step. Returns (coordinator, completed
/// outputs by id, quarantined ids).
fn drive<B: Backend>(
    be: &mut B,
    include_poison: bool,
) -> (Coordinator, BTreeMap<u64, Vec<i32>>, Vec<u64>) {
    let mut c = Coordinator::new(chaos_cfg(), native_cache());
    for i in 0..7u64 {
        c.submit(InferenceRequest {
            id: i,
            adapter: (i as i32 % 4) - 1, // base (-1) and slots 0..2
            prompt: (0..8).map(|k| ((i as i32) * 31 + k * 7 + 3) % 509).collect(),
            max_new_tokens: 40,
            eos_token: None,
            arrival_s: 0.0,
            slo: None,
        });
    }
    if include_poison {
        c.submit(InferenceRequest {
            id: 99,
            adapter: 0,
            prompt: vec![7, POISON, 11],
            max_new_tokens: 8,
            eos_token: None,
            arrival_s: 0.0,
            slo: None,
        });
    }
    c.add_trainer(train_job());

    let mut outputs: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
    let mut quarantined: Vec<u64> = Vec::new();
    let mut steps = 0;
    while !c.quiescent() && steps < 20_000 {
        // Zero engine-loop deaths: every supervised step returns Ok even
        // while faults are firing underneath it.
        let out = c.step(&mut *be).expect("supervised step must absorb injected faults");
        c.kv.audit_ledger().expect("ledger audits clean after every recovery");
        for (id, toks) in out.completed_outputs {
            outputs.insert(id, toks);
        }
        quarantined.extend(out.quarantined_requests);
        if out.idle {
            break;
        }
        steps += 1;
    }
    assert!(c.quiescent(), "chaos workload drained (steps={steps})");
    (c, outputs, quarantined)
}

/// Tentpole acceptance: >= 20 scheduled faults (transient errors, panics,
/// latency spikes) plus a content-poisoned request, against the REAL
/// native numerics. Unaffected streams and the trainer's loss sequence
/// must be bitwise identical to the fault-free run; the poisoned request
/// is quarantined, everything else completes.
#[test]
fn seeded_chaos_run_is_bitwise_transparent_for_unaffected_streams() {
    // Fault-free reference.
    let (mut be_ref, _reg, _m) = HarnessBuilder::new().seed(42).native_stack().unwrap();
    let (ref_c, ref_out, ref_q) = drive(&mut be_ref, false);
    assert!(ref_q.is_empty());
    assert_eq!(ref_out.len(), 7);
    assert_eq!(ref_c.step_retries_total(), 0);
    let ref_losses = ref_c.trainers()[0].losses.clone();
    assert_eq!(ref_losses.len(), 6, "one loss per train sequence");

    // Chaos run: identical model + workload, plus a scripted fault plan.
    // Failing faults sit >= 2 launches apart so each retry (launch k+1)
    // lands clean and no healthy launch ever exhausts its retry budget;
    // spikes don't fail at all. The run has >= ~55 launches (40 decode
    // steps + prefill + 6 train + 3 optim + the retries themselves), so
    // every scheduled index below fires.
    let (inner, _reg2, _m2) = HarnessBuilder::new().seed(42).native_stack().unwrap();
    let mut plan = FaultPlan::new(0xC0FFEE).poison_token(POISON);
    for k in [2u64, 6, 10, 14, 18, 22, 26, 30, 34, 38] {
        plan = plan.at(k, FaultKind::TransientError);
    }
    for k in [4u64, 12, 20, 28, 36] {
        plan = plan.at(k, FaultKind::Panic);
    }
    for k in [8u64, 16, 24, 32, 40] {
        plan = plan.at(k, FaultKind::LatencySpike);
    }
    assert_eq!(plan.scheduled_len(), 20);
    let mut fb = FaultyBackend::new(inner, plan);
    let (chaos_c, chaos_out, chaos_q) = drive(&mut fb, true);

    // >= 20 injected faults (20 scheduled + the poison hits during the
    // whole-class launch and the per-row isolation replay).
    assert!(fb.faults_injected() >= 20, "only {} faults fired", fb.faults_injected());
    assert!(chaos_c.step_retries_total() >= 5, "retries: {}", chaos_c.step_retries_total());

    // The poisoned request — and only it — is quarantined.
    assert_eq!(chaos_q, [99]);
    assert_eq!(chaos_c.quarantined_total(), 1);
    assert_eq!(chaos_c.traces.iter().filter(|t| t.failed).count(), 1);

    // Every stream the faults did not kill is bitwise equal to the
    // fault-free run, token for token.
    assert_eq!(chaos_out.len(), 7, "all healthy requests completed");
    for (id, toks) in &ref_out {
        assert_eq!(chaos_out.get(id), Some(toks), "request {id} output parity");
    }
    // And so is the trainer's loss sequence.
    assert_eq!(chaos_c.trainers()[0].losses, ref_losses, "training loss parity");

    println!(
        "CHAOS_STATS {{\"faults_injected\":{},\"step_retries\":{},\"quarantined\":{},\"parity_ok\":true}}",
        fb.faults_injected(),
        chaos_c.step_retries_total(),
        chaos_c.quarantined_total()
    );
}

/// Crash-restart: run a trainer with auto-checkpointing, kill it after the
/// first durable checkpoint, restore into a FRESH stack, and require the
/// continued loss sequence to equal the uninterrupted run bit-for-bit
/// (Adam moments + bias-correction counter + dataset cursor all survive).
#[test]
fn checkpoint_crash_restart_resumes_losses_bit_identically() {
    let dir = std::env::temp_dir().join("loq-chaos-ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    let train_len = train_job().train_set.len();

    let two_epochs = || FinetuneJob { epochs: 2, ..train_job() };

    // Reference: uninterrupted two-epoch run.
    let (mut be1, _r1, _m1) = HarnessBuilder::new().seed(7).native_stack().unwrap();
    let mut c1 = Coordinator::new(chaos_cfg(), native_cache());
    c1.add_trainer(two_epochs());
    let mut steps = 0;
    while !c1.quiescent() && steps < 10_000 {
        c1.step(&mut be1).unwrap();
        steps += 1;
    }
    let reference = c1.trainers()[0].losses.clone();
    assert_eq!(reference.len(), 2 * train_len);

    // Crash run: checkpoint every 2 optimizer steps; stop dead right
    // after the first checkpoint lands (everything in memory is lost).
    let (mut be2, _r2, _m2) = HarnessBuilder::new().seed(7).native_stack().unwrap();
    let mut c2 = Coordinator::new(
        CoordinatorConfig {
            checkpoint_every: 2,
            checkpoint_dir: Some(dir.clone()),
            ..chaos_cfg()
        },
        native_cache(),
    );
    c2.add_trainer(two_epochs());
    let mut steps = 0;
    while c2.checkpoints_written() == 0 && steps < 10_000 {
        c2.step(&mut be2).unwrap();
        steps += 1;
    }
    let written = c2.checkpoints_written();
    assert!(written >= 1, "auto-checkpoint fired");
    drop(c2);
    drop(be2);

    // Restart: fresh backend (same init seed), restore the durable
    // checkpoint, finish the job.
    let path = dir.join("adapter3.ckpt");
    let ckpt = AdapterCheckpoint::load(&path).unwrap();
    assert_eq!(ckpt.slot, 3);
    let offset = ckpt.epoch * train_len + ckpt.cursor;
    assert!(offset > 0 && offset < reference.len(), "checkpoint mid-run (offset {offset})");
    let (mut be3, _r3, _m3) = HarnessBuilder::new().seed(7).native_stack().unwrap();
    let mut c3 = Coordinator::new(chaos_cfg(), native_cache());
    c3.resume_trainer(two_epochs(), &ckpt, &mut be3).unwrap();
    let mut steps = 0;
    while !c3.quiescent() && steps < 10_000 {
        c3.step(&mut be3).unwrap();
        steps += 1;
    }
    let resumed = c3.trainers()[0].losses.clone();
    assert_eq!(
        resumed.as_slice(),
        &reference[offset..],
        "restored trainer continues the loss sequence bit-identically"
    );

    // Torn/corrupted checkpoints are rejected by the checksum — the
    // optimizer never loads garbage.
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x20;
    std::fs::write(&path, &bytes).unwrap();
    let err = AdapterCheckpoint::load(&path).unwrap_err();
    assert!(format!("{err:#}").contains("checksum"), "{err:#}");

    println!("CHAOS_CKPT {{\"checkpoints_written\":{written},\"loss_parity\":true}}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The serving engine loop under a probabilistic fault storm: healthy
/// generations all complete, the poisoned one comes back as a typed 422
/// quarantine frame, the loop stays alive, and the supervision counters
/// surface through the shared stats the wire frame serializes.
#[test]
fn engine_loop_survives_fault_storm_and_quarantines_poison() {
    let (frontend, rx) = Frontend::new(AdmissionConfig::default());
    let fe = frontend.clone();
    let engine = std::thread::spawn(move || {
        let mut coord = Coordinator::new(
            CoordinatorConfig {
                max_prompt_tokens: 32,
                drop_after_s: 1e9,
                // Probabilistic faults can cluster; a deeper retry budget
                // makes a healthy launch exhausting it (p^7) negligible.
                max_step_retries: 6,
                ..Default::default()
            },
            sim_cache_config(),
        );
        let plan = FaultPlan::new(99)
            .error_rate(0.10)
            .panic_rate(0.05)
            .latency_rate(0.05)
            .poison_token(POISON);
        let mut be = FaultyBackend::new(sim_backend(CostModel::default()), plan);
        let mut dir = StaticDirectory::new(4, 8);
        let res = engine_loop(&mut coord, &mut be, &mut dir, &rx, &fe);
        assert!(res.is_ok(), "engine loop died under the storm: {res:?}");
    });

    // 12 healthy generations + 1 poisoned, at the EngineMsg layer.
    let mut healthy = Vec::new();
    for i in 0..12u64 {
        let (tx, erx) = channel();
        frontend
            .send(EngineMsg::Generate(GenerateJob {
                id: i + 1,
                model: None,
                prompt: vec![1 + i as i32, 2, 3],
                max_new_tokens: 8,
                slo: Default::default(),
                events: tx,
            }))
            .unwrap();
        healthy.push((i + 1, erx));
    }
    let (ptx, prx) = channel();
    frontend
        .send(EngineMsg::Generate(GenerateJob {
            id: 1000,
            model: None,
            prompt: vec![5, POISON, 9],
            max_new_tokens: 4,
            slo: Default::default(),
            events: ptx,
        }))
        .unwrap();

    for (id, erx) in healthy {
        loop {
            match erx.recv_timeout(Duration::from_secs(60)).unwrap() {
                TokenEvent::Token { .. } => {}
                TokenEvent::Done { tokens, .. } => {
                    assert_eq!(tokens.len(), 8, "request {id}");
                    break;
                }
                TokenEvent::Error { code, msg } => {
                    panic!("healthy request {id} failed: {code:?} {msg}")
                }
            }
        }
    }
    loop {
        match prx.recv_timeout(Duration::from_secs(60)).unwrap() {
            TokenEvent::Error { code, msg } => {
                assert_eq!(code, ErrCode::Quarantined, "{msg}");
                assert_eq!(code.code(), 422);
                break;
            }
            TokenEvent::Done { .. } => panic!("poisoned request completed"),
            TokenEvent::Token { .. } => {}
        }
    }

    // Still alive and serving after the storm.
    let (tx, erx) = channel();
    frontend
        .send(EngineMsg::Generate(GenerateJob {
            id: 2000,
            model: None,
            prompt: vec![4, 4],
            max_new_tokens: 2,
            slo: Default::default(),
            events: tx,
        }))
        .unwrap();
    loop {
        match erx.recv_timeout(Duration::from_secs(60)).unwrap() {
            TokenEvent::Done { tokens, .. } => {
                assert_eq!(tokens.len(), 2);
                break;
            }
            TokenEvent::Error { code, msg } => panic!("post-storm request failed: {code:?} {msg}"),
            TokenEvent::Token { .. } => {}
        }
    }

    // Graceful drain, then read the counters the stats frame serializes.
    let (dtx, drx) = channel();
    frontend.send(EngineMsg::Shutdown { reply: dtx }).unwrap();
    drx.recv_timeout(Duration::from_secs(60)).unwrap();
    engine.join().unwrap();
    let s = frontend.stats.lock().unwrap();
    assert!(s.faults_injected >= 1, "storm injected nothing");
    assert_eq!(s.quarantined, 1);
    println!(
        "CHAOS_FRAME {{\"faults_injected\":{},\"step_retries\":{},\"quarantined\":{},\"checkpoints_written\":{},\"backend_resets\":{}}}",
        s.faults_injected, s.step_retries, s.quarantined, s.checkpoints_written, s.backend_resets
    );
}
