//! Native-backend numerics goldens (ISSUE 2 + ISSUE 3): the segmented
//! SMLM kernel against its per-row reference, end-to-end through the
//! backend, bit-level determinism of the whole prefill→decode→train→optim
//! flow, bitwise `threads=1` vs `threads=N` parity of the parallel kernel
//! runtime, and stale-data isolation of the scratch arena. Runs
//! unconditionally — no artifacts, no PJRT, no skips.

use loquetier::engine::{Backend, DecodeRow, NativeBackend, PrefillSeq, TrainSeq, UnifiedOut};
use loquetier::harness::{cache_config_for, native_geometry, HarnessBuilder};
use loquetier::kvcache::KvCacheManager;
use loquetier::model::VirtualizedRegistry;
use loquetier::runtime::Manifest;

fn stack(seed: u64) -> (NativeBackend, VirtualizedRegistry, Manifest) {
    HarnessBuilder::new().seed(seed).native_stack().unwrap()
}

fn stack_t(seed: u64, threads: usize) -> (NativeBackend, VirtualizedRegistry, Manifest) {
    HarnessBuilder::new().seed(seed).threads(threads).native_stack().unwrap()
}

fn cache() -> KvCacheManager {
    KvCacheManager::new(cache_config_for(&native_geometry(), 16))
}

fn toks(len: usize, salt: i32) -> Vec<i32> {
    let v = native_geometry().vocab_size as i32;
    (0..len as i32).map(|i| (salt * 37 + i * 11 + 5).rem_euclid(v)).collect()
}

/// A mixed-adapter prefill batch: every bank slot, a repeated slot, and
/// base-only rows (`adapter = -1`) interleaved.
fn mixed_batch(kv: &mut KvCacheManager) -> Vec<PrefillSeq> {
    let adapters = [0i32, -1, 1, 2, 3, 0, -1, 2];
    adapters
        .iter()
        .enumerate()
        .map(|(i, &a)| PrefillSeq {
            tokens: toks(6 + i % 5, i as i32),
            adapter: a,
            kv_slot: kv.allocate(i as u64, 32).unwrap(),
        })
        .collect()
}

#[test]
fn segmented_smlm_matches_per_row_reference_on_mixed_batch() {
    // Same seed, two kernel paths: logits must agree within 1e-5 across a
    // batch mixing every adapter, duplicate adapters, and base-only rows.
    let (mut seg, _r1, _m1) = stack(77);
    let (mut per, _r2, _m2) = stack(77);
    assert!(seg.use_segmented);
    per.use_segmented = false;

    let mut kv_a = cache();
    let mut kv_b = cache();
    let batch_a = mixed_batch(&mut kv_a);
    let batch_b = mixed_batch(&mut kv_b);
    let (la, _) = seg.prefill(&batch_a, &mut kv_a).unwrap();
    let (lb, _) = per.prefill(&batch_b, &mut kv_b).unwrap();
    assert_eq!(la.len(), lb.len());
    for (i, (ra, rb)) in la.iter().zip(&lb).enumerate() {
        let mut worst = 0.0f32;
        for (a, b) in ra.iter().zip(rb) {
            worst = worst.max((a - b).abs());
        }
        assert!(worst < 1e-5, "seq {i}: segmented vs per-row diverged by {worst}");
    }

    // Decode rows over the (identical) caches must agree too.
    let rows_a: Vec<DecodeRow> = batch_a
        .iter()
        .map(|q| DecodeRow { token: 13, adapter: q.adapter, kv_slot: q.kv_slot })
        .collect();
    let rows_b: Vec<DecodeRow> = batch_b
        .iter()
        .map(|q| DecodeRow { token: 13, adapter: q.adapter, kv_slot: q.kv_slot })
        .collect();
    let (da, _) = seg.decode(&rows_a, &mut kv_a).unwrap();
    let (db, _) = per.decode(&rows_b, &mut kv_b).unwrap();
    for (i, (ra, rb)) in da.iter().zip(&db).enumerate() {
        let mut worst = 0.0f32;
        for (a, b) in ra.iter().zip(rb) {
            worst = worst.max((a - b).abs());
        }
        assert!(worst < 1e-5, "decode row {i}: diverged by {worst}");
    }
}

#[test]
fn segmented_smlm_matches_per_row_on_training_losses() {
    let (mut seg, _r1, _m1) = stack(31);
    let (mut per, _r2, _m2) = stack(31);
    per.use_segmented = false;
    let batch: Vec<TrainSeq> = [0i32, 2, -1, 3]
        .iter()
        .enumerate()
        .map(|(i, &a)| TrainSeq {
            tokens: toks(12, i as i32),
            labels: toks(12, i as i32),
            adapter: a,
            train: true,
            loss_scale: 0.5,
        })
        .collect();
    let (la, _) = seg.train_step(&batch).unwrap();
    let (lb, _) = per.train_step(&batch).unwrap();
    for (i, (a, b)) in la.iter().zip(&lb).enumerate() {
        assert!((a - b).abs() < 1e-5, "loss {i}: {a} vs {b}");
    }
}

#[test]
fn same_seed_is_bitwise_deterministic() {
    // Two full flows from the same seed: every emitted token and every
    // loss must be IDENTICAL (bitwise) — prefill, decode chain, training,
    // optimizer and post-optimizer inference.
    let run = || -> (Vec<i32>, Vec<f32>) {
        let (mut be, _reg, _m) = stack(123);
        let mut kv = cache();
        let mut tokens_out = Vec::new();
        let mut losses_out = Vec::new();

        let slot = kv.allocate(1, 64).unwrap();
        let (logits, _) = be
            .prefill(&[PrefillSeq { tokens: toks(10, 4), adapter: 1, kv_slot: slot }], &mut kv)
            .unwrap();
        let mut next = loquetier::engine::argmax(&logits[0]);
        tokens_out.push(next);
        for _ in 0..6 {
            let (lg, _) = be
                .decode(&[DecodeRow { token: next, adapter: 1, kv_slot: slot }], &mut kv)
                .unwrap();
            next = loquetier::engine::argmax(&lg[0]);
            tokens_out.push(next);
        }

        for step in 1..=3 {
            let (l, _) = be
                .train_step(&[TrainSeq {
                    tokens: toks(14, 8),
                    labels: toks(14, 8),
                    adapter: 2,
                    train: true,
                    loss_scale: 1.0,
                }])
                .unwrap();
            losses_out.extend_from_slice(&l);
            be.optim_step(&[2], 5e-3, step).unwrap();
        }
        // Post-training inference reflects the updated adapter,
        // deterministically.
        let slot2 = kv.allocate(2, 32).unwrap();
        let (lg2, _) = be
            .prefill(&[PrefillSeq { tokens: toks(8, 2), adapter: 2, kv_slot: slot2 }], &mut kv)
            .unwrap();
        tokens_out.push(loquetier::engine::argmax(&lg2[0]));
        (tokens_out, losses_out)
    };

    let (t1, l1) = run();
    let (t2, l2) = run();
    assert_eq!(t1, t2, "token stream must be deterministic");
    assert_eq!(l1.len(), l2.len());
    for (a, b) in l1.iter().zip(&l2) {
        assert_eq!(a.to_bits(), b.to_bits(), "losses must be bit-identical");
    }
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
    }
}

#[test]
fn thread_counts_are_bitwise_identical_on_mixed_unified_flow() {
    // The ISSUE 3 acceptance test (sweep widened to t ∈ {1, 2, 4, 8} for
    // the ISSUE 7 blocked GEMM): the SAME mixed workload — a unified
    // fine-tune ∥ prefill ∥ decode launch with adapter and base-only
    // (`adapter = -1`) rows, a decode chain, an optimizer step and a
    // post-training prefill — must produce bitwise-identical logits,
    // tokens and losses at every pool width. Parallelism is
    // partition-only and blocking is shape-derived, so no thread count
    // may change a single bit.
    let run = |threads: usize| -> (Vec<Vec<f32>>, Vec<f32>, Vec<i32>) {
        let (mut be, _reg, _m) = stack_t(321, threads);
        let mut kv = cache();
        let mut all_logits: Vec<Vec<f32>> = Vec::new();
        let mut all_losses: Vec<f32> = Vec::new();
        let mut tokens_out: Vec<i32> = Vec::new();

        // Warm two KV slots so the unified decode rows have history.
        let warm: Vec<PrefillSeq> = [(0i32, 0u64), (-1, 1)]
            .iter()
            .map(|&(a, id)| PrefillSeq {
                tokens: toks(7, a + 3),
                adapter: a,
                kv_slot: kv.allocate(id, 48).unwrap(),
            })
            .collect();
        let (lg, _) = be.prefill(&warm, &mut kv).unwrap();
        all_logits.extend(lg);

        // One unified launch: train (adapter + base-only eval) ∥ prefill
        // (adapter + base-only) ∥ decode over the warmed slots.
        let ft: Vec<TrainSeq> = [(2i32, true), (-1, false)]
            .iter()
            .map(|&(a, train)| TrainSeq {
                tokens: toks(12, a + 9),
                labels: toks(12, a + 9),
                adapter: a,
                train,
                loss_scale: 0.5,
            })
            .collect();
        let pf: Vec<PrefillSeq> = [(1i32, 10u64), (-1, 11)]
            .iter()
            .map(|&(a, id)| PrefillSeq {
                tokens: toks(6, a),
                adapter: a,
                kv_slot: kv.allocate(id, 32).unwrap(),
            })
            .collect();
        let dec: Vec<DecodeRow> = warm
            .iter()
            .map(|q| DecodeRow { token: 5, adapter: q.adapter, kv_slot: q.kv_slot })
            .collect();
        let (out, _): (UnifiedOut, _) = be.unified(&ft, &pf, &dec, &mut kv).unwrap();
        all_losses.extend(&out.ft_losses);
        all_logits.extend(out.pf_last_logits);
        all_logits.extend(out.dec_logits);

        // Decode chain + optimizer + post-training prefill.
        let slot = pf[0].kv_slot;
        let mut next = 9i32;
        for _ in 0..4 {
            let (lg, _) = be
                .decode(&[DecodeRow { token: next, adapter: 1, kv_slot: slot }], &mut kv)
                .unwrap();
            next = loquetier::engine::argmax(&lg[0]);
            tokens_out.push(next);
            all_logits.extend(lg);
        }
        be.optim_step(&[2], 5e-3, 1).unwrap();
        let slot2 = kv.allocate(20, 32).unwrap();
        let (lg, _) = be
            .prefill(&[PrefillSeq { tokens: toks(8, 4), adapter: 2, kv_slot: slot2 }], &mut kv)
            .unwrap();
        tokens_out.push(loquetier::engine::argmax(&lg[0]));
        all_logits.extend(lg);
        (all_logits, all_losses, tokens_out)
    };

    let (lg1, ls1, tk1) = run(1);
    for threads in [2usize, 4, 8] {
        let (lgn, lsn, tkn) = run(threads);
        assert_eq!(tk1, tkn, "t{threads}: emitted tokens must not depend on thread count");
        assert_bits_eq(&ls1, &lsn, &format!("t{threads} losses"));
        assert_eq!(lg1.len(), lgn.len());
        for (i, (a, b)) in lg1.iter().zip(&lgn).enumerate() {
            assert_bits_eq(a, b, &format!("t{threads} logits row {i}"));
        }
    }
}

#[test]
fn scratch_arena_reuse_leaks_no_stale_state() {
    // Backend A churns its arena with differently-shaped steps (longer
    // training sequence, a prefill+decode launch), then runs a probe;
    // fresh backend B runs ONLY the probe. Bitwise-equal probe outputs
    // prove a claimed buffer never exposes a previous step's data.
    let probe_train = || TrainSeq {
        tokens: toks(9, 1),
        labels: toks(9, 1),
        adapter: 1,
        train: false,
        loss_scale: 1.0,
    };
    let probe_prefill = |be: &mut dyn Backend| -> Vec<Vec<f32>> {
        let mut kv = cache();
        let seqs: Vec<PrefillSeq> = [0i32, -1]
            .iter()
            .enumerate()
            .map(|(i, &a)| PrefillSeq {
                tokens: toks(5 + i, 2),
                adapter: a,
                kv_slot: kv.allocate(i as u64, 32).unwrap(),
            })
            .collect();
        be.prefill(&seqs, &mut kv).unwrap().0
    };

    let (mut dirty, _r1, _m1) = stack_t(99, 2);
    let (mut fresh, _r2, _m2) = stack_t(99, 2);

    // Pollute: a longer eval step and a bigger inference launch fill the
    // arena with non-zero buffers of every hot shape.
    dirty
        .train_step(&[TrainSeq {
            tokens: toks(20, 5),
            labels: toks(20, 5),
            adapter: 2,
            train: false,
            loss_scale: 1.0,
        }])
        .unwrap();
    {
        let mut kv = cache();
        let seqs: Vec<PrefillSeq> = (0..4)
            .map(|i| PrefillSeq {
                tokens: toks(11, i),
                adapter: i % 3 - 1,
                kv_slot: kv.allocate(i as u64, 32).unwrap(),
            })
            .collect();
        dirty.prefill(&seqs, &mut kv).unwrap();
    }

    let (la, _) = dirty.train_step(&[probe_train()]).unwrap();
    let (lb, _) = fresh.train_step(&[probe_train()]).unwrap();
    assert_bits_eq(&la, &lb, "probe losses");
    let pa = probe_prefill(&mut dirty);
    let pb = probe_prefill(&mut fresh);
    for (i, (a, b)) in pa.iter().zip(&pb).enumerate() {
        assert_bits_eq(a, b, &format!("probe logits {i}"));
    }
}

#[test]
fn host_tier_eviction_roundtrip_is_output_transparent() {
    // ISSUE 6 acceptance: evicting resident adapters to the host tier
    // mid-run and swapping them back in (unified paging, DESIGN.md §10)
    // must not change a single emitted bit. Tokens and trainer losses are
    // compared bitwise against a never-evicted run, on 1 and 4 threads.
    let run = |threads: usize, evict: bool| -> (Vec<i32>, Vec<f32>) {
        let (mut be, mut reg, _m) = stack_t(777, threads);
        let mut kv = cache();
        let mut tokens = Vec::new();
        let mut losses = Vec::new();

        // Phase 1: serve on adapter 1, fine-tune adapter 2.
        let slot = kv.allocate(1, 64).unwrap();
        let (lg, _) = be
            .prefill(&[PrefillSeq { tokens: toks(10, 4), adapter: 1, kv_slot: slot }], &mut kv)
            .unwrap();
        let mut next = loquetier::engine::argmax(&lg[0]);
        tokens.push(next);
        for _ in 0..3 {
            let (lg, _) = be
                .decode(&[DecodeRow { token: next, adapter: 1, kv_slot: slot }], &mut kv)
                .unwrap();
            next = loquetier::engine::argmax(&lg[0]);
            tokens.push(next);
        }
        for step in 1..=2 {
            let (l, _) = be
                .train_step(&[TrainSeq {
                    tokens: toks(14, 8),
                    labels: toks(14, 8),
                    adapter: 2,
                    train: true,
                    loss_scale: 1.0,
                }])
                .unwrap();
            losses.extend_from_slice(&l);
            be.optim_step(&[2], 5e-3, step).unwrap();
        }
        // Eviction parks the registry's bank mirror, so pull the trained
        // weights into it first (the Finetune checkpoint rule).
        be.checkpoint_adapters(&mut reg).unwrap();

        if evict {
            // Swap-out: both the serving and the trained adapter leave the
            // device; after the sync the backend has really lost them.
            let k1 = reg.evict_to_host(1).unwrap();
            let k2 = reg.evict_to_host(2).unwrap();
            be.sync_adapters(&mut reg).unwrap();
            assert!(reg.on_host(&k1) && reg.on_host(&k2));
            assert_eq!(reg.resident_slot(&k1), None);
            // Swap-in reuses the lowest free slot, restoring 1 then 2 —
            // which keeps the backend's slot-keyed optimizer state valid.
            assert_eq!(reg.swap_in(&k1).unwrap(), 1);
            assert_eq!(reg.swap_in(&k2).unwrap(), 2);
            be.sync_adapters(&mut reg).unwrap();
        }

        // Phase 2: decode continues the SAME KV slot on adapter 1;
        // training continues on adapter 2 with the optimizer moments that
        // stayed in the backend across the round trip.
        for _ in 0..3 {
            let (lg, _) = be
                .decode(&[DecodeRow { token: next, adapter: 1, kv_slot: slot }], &mut kv)
                .unwrap();
            next = loquetier::engine::argmax(&lg[0]);
            tokens.push(next);
        }
        for step in 3..=4 {
            let (l, _) = be
                .train_step(&[TrainSeq {
                    tokens: toks(14, 8),
                    labels: toks(14, 8),
                    adapter: 2,
                    train: true,
                    loss_scale: 1.0,
                }])
                .unwrap();
            losses.extend_from_slice(&l);
            be.optim_step(&[2], 5e-3, step).unwrap();
        }
        let slot2 = kv.allocate(2, 32).unwrap();
        let (lg2, _) = be
            .prefill(&[PrefillSeq { tokens: toks(8, 2), adapter: 2, kv_slot: slot2 }], &mut kv)
            .unwrap();
        tokens.push(loquetier::engine::argmax(&lg2[0]));
        (tokens, losses)
    };

    for threads in [1usize, 4] {
        let (t_stay, l_stay) = run(threads, false);
        let (t_swap, l_swap) = run(threads, true);
        assert_eq!(t_stay, t_swap, "threads={threads}: tokens must not see the swap");
        assert_bits_eq(&l_stay, &l_swap, &format!("threads={threads} trainer losses"));
    }
}

#[test]
fn prefix_reuse_is_output_transparent() {
    // ISSUE 10 acceptance: attaching a sequence to published shared-prefix
    // blocks (radix index, DESIGN.md §14) must not change a single emitted
    // bit vs prefilling the same prompt cold — tokens AND trainer losses,
    // bitwise, on 1 and 4 threads, with a forced mid-stream preemption of
    // the sharer (drop refs, recompute-on-resume re-attaches).
    let prefix = toks(32, 40); // two full 16-token blocks
    let run = |threads: usize, shared: bool| -> (Vec<i32>, Vec<f32>) {
        let (mut be, _reg, _m) = stack_t(999, threads);
        let mut kv = cache();
        if shared {
            kv.enable_prefix_sharing();
        }
        let mut tokens = Vec::new();
        let mut losses = Vec::new();

        // Publisher A: full prompt (prefix + its own suffix), prefilled
        // whole, then published into the index (shared mode only).
        let mut pa = prefix.clone();
        pa.extend_from_slice(&toks(9, 41));
        let (slot_a, hit_a) = kv.allocate_shared(1, pa.len(), 1, &pa).unwrap();
        assert_eq!(hit_a, 0, "empty index: the publisher must miss");
        let (lg, _) = be
            .prefill(&[PrefillSeq { tokens: pa.clone(), adapter: 1, kv_slot: slot_a }], &mut kv)
            .unwrap();
        let mut next_a = loquetier::engine::argmax(&lg[0]);
        tokens.push(next_a);
        if shared {
            kv.publish_prefix(slot_a, 1, &pa);
        }

        // Sharer B: same adapter and prefix, different suffix. Shared mode
        // attaches to the two cached blocks and prefills only the suffix
        // (a shorter slice — PrefillSlice semantics); cold prefills whole.
        let mut pb = prefix.clone();
        pb.extend_from_slice(&toks(7, 42));
        let (slot_b, hit_b) = kv.allocate_shared(2, pb.len(), 1, &pb).unwrap();
        assert_eq!(hit_b, if shared { 32 } else { 0 });
        let (lg, _) = be
            .prefill(
                &[PrefillSeq { tokens: pb[hit_b..].to_vec(), adapter: 1, kv_slot: slot_b }],
                &mut kv,
            )
            .unwrap();
        let mut next_b = loquetier::engine::argmax(&lg[0]);
        let mut gen_b = vec![next_b];
        tokens.push(next_b);

        // Interleaved decodes on both streams: B's attention reads the
        // shared blocks through the translation table, A's its own arena.
        for _ in 0..2 {
            let (lg, _) = be
                .decode(&[DecodeRow { token: next_b, adapter: 1, kv_slot: slot_b }], &mut kv)
                .unwrap();
            next_b = loquetier::engine::argmax(&lg[0]);
            gen_b.push(next_b);
            tokens.push(next_b);
            let (lg, _) = be
                .decode(&[DecodeRow { token: next_a, adapter: 1, kv_slot: slot_a }], &mut kv)
                .unwrap();
            next_a = loquetier::engine::argmax(&lg[0]);
            tokens.push(next_a);
        }

        // A trainer on another adapter; its optimizer step invalidates
        // that adapter's (absent) prefix subtree — the §14 staleness rule
        // must not perturb adapter 1's cached chain.
        let (l, _) = be
            .train_step(&[TrainSeq {
                tokens: toks(14, 8),
                labels: toks(14, 8),
                adapter: 2,
                train: true,
                loss_scale: 1.0,
            }])
            .unwrap();
        losses.extend_from_slice(&l);
        be.optim_step(&[2], 5e-3, 1).unwrap();
        kv.invalidate_adapter_prefixes(2);

        // Forced preemption of the sharer mid-stream: release drops its
        // chain refs; recompute-on-resume folds the generated tokens into
        // the prompt and (shared mode) re-attaches to the still-published
        // prefix, recomputing only the folded tail.
        let mut folded = pb.clone();
        folded.extend_from_slice(&gen_b);
        kv.release(slot_b).unwrap();
        let (slot_b2, hit2) = kv.allocate_shared(2, folded.len(), 1, &folded).unwrap();
        assert_eq!(hit2, if shared { 32 } else { 0 });
        let (lg, _) = be
            .prefill(
                &[PrefillSeq { tokens: folded[hit2..].to_vec(), adapter: 1, kv_slot: slot_b2 }],
                &mut kv,
            )
            .unwrap();
        next_b = loquetier::engine::argmax(&lg[0]);
        tokens.push(next_b);
        for _ in 0..2 {
            let (lg, _) = be
                .decode(&[DecodeRow { token: next_b, adapter: 1, kv_slot: slot_b2 }], &mut kv)
                .unwrap();
            next_b = loquetier::engine::argmax(&lg[0]);
            tokens.push(next_b);
        }
        (tokens, losses)
    };

    for threads in [1usize, 4] {
        let (t_cold, l_cold) = run(threads, false);
        let (t_shared, l_shared) = run(threads, true);
        assert_eq!(
            t_cold, t_shared,
            "threads={threads}: prefix sharing must be invisible in emitted tokens"
        );
        assert_bits_eq(&l_cold, &l_shared, &format!("threads={threads} trainer losses"));
    }
}

#[test]
fn different_seeds_produce_different_models() {
    let (mut a, _ra, _ma) = stack(1);
    let (mut b, _rb, _mb) = stack(2);
    let mut kv_a = cache();
    let mut kv_b = cache();
    let sa = kv_a.allocate(1, 32).unwrap();
    let sb = kv_b.allocate(1, 32).unwrap();
    let (la, _) = a
        .prefill(&[PrefillSeq { tokens: toks(8, 1), adapter: -1, kv_slot: sa }], &mut kv_a)
        .unwrap();
    let (lb, _) = b
        .prefill(&[PrefillSeq { tokens: toks(8, 1), adapter: -1, kv_slot: sb }], &mut kv_b)
        .unwrap();
    let diff: f32 = la[0].iter().zip(&lb[0]).map(|(x, y)| (x - y).abs()).sum();
    assert!(diff > 1e-3, "seeds must produce distinct weights");
}

#[test]
fn training_gradients_flow_only_through_trained_slot() {
    // Train slot 3; logits through untouched slots (and base) must be
    // bit-identical before/after the optimizer step.
    let (mut be, _reg, _m) = stack(55);
    let probe = |be: &mut dyn Backend| -> Vec<Vec<f32>> {
        let mut kv = cache();
        let seqs: Vec<PrefillSeq> = [0i32, -1]
            .iter()
            .enumerate()
            .map(|(i, &a)| PrefillSeq {
                tokens: toks(9, 6),
                adapter: a,
                kv_slot: kv.allocate(i as u64, 32).unwrap(),
            })
            .collect();
        be.prefill(&seqs, &mut kv).unwrap().0
    };
    let before = probe(&mut be);
    for step in 1..=2 {
        be.train_step(&[TrainSeq {
            tokens: toks(12, 3),
            labels: toks(12, 3),
            adapter: 3,
            train: true,
            loss_scale: 1.0,
        }])
        .unwrap();
        be.optim_step(&[3], 1e-2, step).unwrap();
    }
    let after = probe(&mut be);
    for (b, a) in before.iter().zip(&after) {
        for (x, y) in b.iter().zip(a) {
            assert_eq!(x.to_bits(), y.to_bits(), "untrained slots must be untouched");
        }
    }
}

#[test]
fn int8_base_weights_track_f32_serving_within_documented_bound() {
    // The ISSUE 7 quantization tolerance contract (DESIGN.md §11): with
    // `--quantized`, serving logits may deviate from the f32 path by at
    // most 5e-2 of the row's largest f32 logit magnitude (per-GEMM the
    // bound is 1e-2 — unit-tested in kernels.rs — and two layers plus the
    // lm_head compound it). The f32 path itself never loosens: training on
    // the quantized backend still reads the f32 masters and must stay
    // bitwise identical to the plain backend.
    const E2E_REL_BOUND: f32 = 5e-2;
    let (mut base, _r1, _m1) = stack(2025);
    let (mut quant, _r2, _m2) =
        HarnessBuilder::new().seed(2025).quantized(true).native_stack().unwrap();
    assert!(!base.is_quantized());
    assert!(quant.is_quantized());

    let mut kv_a = cache();
    let mut kv_b = cache();
    let batch_a = mixed_batch(&mut kv_a);
    let batch_b = mixed_batch(&mut kv_b);
    let rel_check = |la: &[Vec<f32>], lb: &[Vec<f32>], what: &str| {
        assert_eq!(la.len(), lb.len());
        for (i, (ra, rb)) in la.iter().zip(lb).enumerate() {
            let scale = ra.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
            let worst = ra.iter().zip(rb).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
            assert!(
                worst <= E2E_REL_BOUND * scale,
                "{what} {i}: int8 vs f32 rel err {} > {E2E_REL_BOUND}",
                worst / scale
            );
        }
    };
    let (la, _) = base.prefill(&batch_a, &mut kv_a).unwrap();
    let (lb, _) = quant.prefill(&batch_b, &mut kv_b).unwrap();
    rel_check(&la, &lb, "prefill seq");

    let rows = |batch: &[PrefillSeq]| -> Vec<DecodeRow> {
        batch
            .iter()
            .map(|q| DecodeRow { token: 13, adapter: q.adapter, kv_slot: q.kv_slot })
            .collect()
    };
    let (da, _) = base.decode(&rows(&batch_a), &mut kv_a).unwrap();
    let (db, _) = quant.decode(&rows(&batch_b), &mut kv_b).unwrap();
    rel_check(&da, &db, "decode row");

    // Training path: bitwise equal — quantization is inference-only.
    let train_batch: Vec<TrainSeq> = [1i32, -1, 3]
        .iter()
        .enumerate()
        .map(|(i, &a)| TrainSeq {
            tokens: toks(12, i as i32),
            labels: toks(12, i as i32),
            adapter: a,
            train: true,
            loss_scale: 0.5,
        })
        .collect();
    let (lt_a, _) = base.train_step(&train_batch).unwrap();
    let (lt_b, _) = quant.train_step(&train_batch).unwrap();
    assert_bits_eq(&lt_a, &lt_b, "train losses under quantized serving");
}
