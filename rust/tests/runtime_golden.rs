//! Integration goldens over the load path.
//!
//! Two tiers:
//!
//! * **Synthetic (unconditional).** `HarnessBuilder::native_model` builds an
//!   in-memory manifest + weight store shaped exactly like `make
//!   artifacts` output — registry rebuild, detach/migration, adapter
//!   save/load and store bounds all run with zero artifacts.
//! * **Artifact-backed (skip-on-absent).** The HLO-text → PJRT compile →
//!   execute path against `artifacts/golden/*.json` snapshots from the
//!   Python side still requires `make artifacts` and the real `xla`
//!   bindings (DESIGN.md §3 S7).

use std::path::{Path, PathBuf};

use loquetier::harness::HarnessBuilder;
use loquetier::model::{LoraAdapter, SlotState, VirtualizedRegistry, WeightStore};
use loquetier::runtime::{Arg, DType, HostTensor, Manifest, Runtime, TensorSpec};
use loquetier::util::json;

/// None = artifacts absent: skip the artifact-backed tier only.
fn artifacts_dir() -> Option<PathBuf> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let dir = root.join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing — run `make artifacts` first");
        return None;
    }
    Some(dir)
}

fn synthetic() -> (Manifest, WeightStore) {
    HarnessBuilder::new().seed(2024).native_model().expect("synthetic model")
}

// ---------------------------------------------------------------------------
// Synthetic tier — unconditional
// ---------------------------------------------------------------------------

/// The virtualized registry, given base + adapter records, must rebuild
/// exactly the `bank.*` arrays the store preloads (attach = slot write).
/// Runs against the synthetic store unconditionally AND against real
/// artifacts when present — the latter is the cross-language contract
/// (the bank arrays there were written by Python's aot.py).
fn check_registry_rebuild(manifest: &Manifest, store: &WeightStore) {
    let mut reg = VirtualizedRegistry::new(manifest, store).unwrap();
    for i in 0..manifest.build.lora.max_adapters {
        let ad = LoraAdapter::from_store(store, manifest, i, format!("a{i}")).unwrap();
        reg.attach(format!("vm{i}"), ad, i, SlotState::Inference).unwrap();
    }
    for name in manifest.lora_param_names() {
        let bank_name = format!("bank.{}", name.strip_prefix("lora.").unwrap());
        let want = store.tensor(&bank_name).unwrap();
        let got = reg.bank_tensor(&name).unwrap();
        assert_eq!(got.shape, want.shape, "{name}");
        let (gv, wv) = (got.as_f32().unwrap(), want.as_f32().unwrap());
        assert_eq!(gv, wv, "{name}: rebuilt bank differs from preloaded bank");
    }
}

#[test]
fn registry_rebuild_matches_bank_records() {
    let (manifest, store) = synthetic();
    check_registry_rebuild(&manifest, &store);
}

#[test]
fn detach_zeroes_slot_and_migration_roundtrips() {
    let (manifest, store) = synthetic();
    let mut reg = VirtualizedRegistry::new(&manifest, &store).unwrap();
    let ad = LoraAdapter::from_store(&store, &manifest, 0, "a0").unwrap();
    reg.attach("vm0", ad, 2, SlotState::Inference).unwrap();

    // void() detaches and returns a payload re-attachable elsewhere.
    let payload = reg.void(2).unwrap();
    let t = reg.bank_tensor("lora.layers.0.q.a").unwrap();
    let l = manifest.build.lora.max_adapters;
    let per = t.element_count() / l;
    assert!(t.as_f32().unwrap()[2 * per..3 * per].iter().all(|&x| x == 0.0));

    let mut reg2 = VirtualizedRegistry::new(&manifest, &store).unwrap();
    reg2.unvoid(payload, 1).unwrap();
    let t2 = reg2.bank_tensor("lora.layers.0.q.a").unwrap();
    let a0 = store.tensor("adapter0.layers.0.q.a").unwrap();
    assert_eq!(
        &t2.as_f32().unwrap()[per..2 * per],
        a0.as_f32().unwrap(),
        "migrated adapter must land bit-identical in the new slot"
    );
}

#[test]
fn adapter_save_load_roundtrip() {
    let (manifest, store) = synthetic();
    let ad = LoraAdapter::from_store(&store, &manifest, 1, "roundtrip").unwrap();
    let tmp = std::env::temp_dir().join("loq_adapter_roundtrip.json");
    ad.save(&tmp).unwrap();
    let back = LoraAdapter::load(&tmp).unwrap();
    assert_eq!(back.name, ad.name);
    assert_eq!(back.modules.len(), ad.modules.len());
    for (k, m) in &ad.modules {
        let bm = &back.modules[k];
        assert_eq!(bm.a_shape, m.a_shape);
        for (x, y) in bm.a.iter().zip(&m.a) {
            assert!((x - y).abs() < 1e-6);
        }
    }
    back.validate(&manifest).unwrap();
}

#[test]
fn weight_store_rejects_missing_and_validates_bounds() {
    let (manifest, store) = synthetic();
    assert!(store.tensor("no.such.weight").is_err());
    assert!(store.contains("base.embed"));
    assert!(store.total_bytes() > 0);
    // from_parts re-validates bounds: a record past the blob is rejected.
    let mut records = manifest.weights.clone();
    records[0].offset = store.total_bytes();
    assert!(WeightStore::from_parts(records, vec![0u8; store.total_bytes()]).is_err());
}

#[test]
fn import_bank_overwrites_host_mirror() {
    let (manifest, store) = synthetic();
    let mut reg = VirtualizedRegistry::new(&manifest, &store).unwrap();
    let name = "lora.layers.0.q.a";
    let n = reg.bank_tensor(name).unwrap().element_count();
    let marker: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
    reg.import_bank(name, &marker).unwrap();
    assert_eq!(reg.bank_tensor(name).unwrap().as_f32().unwrap(), &marker[..]);
    assert!(reg.import_bank(name, &marker[..n - 1]).is_err(), "length checked");
    assert!(reg.import_bank("lora.layers.0.q.nope", &marker).is_err());
}

#[test]
fn checkpoint_evict_reattach_roundtrip_is_bit_identical() {
    // Unified-paging golden (DESIGN.md §10): checkpoint → evict → swap_in
    // must round-trip a *trained* bank bit-identically, and the registry
    // must reuse freed slots lowest-first for both `swap_in` and
    // `attach_auto`.
    let (manifest, store) = synthetic();
    let mut reg = VirtualizedRegistry::new(&manifest, &store).unwrap();
    let a0 = LoraAdapter::from_store(&store, &manifest, 0, "a0").unwrap();
    let a1 = LoraAdapter::from_store(&store, &manifest, 1, "a1").unwrap();
    assert_eq!(reg.attach_auto("vm0", a0, SlotState::Inference).unwrap().slot, 0);
    assert_eq!(reg.attach_auto("vm1", a1, SlotState::Inference).unwrap().slot, 1);

    // "Checkpoint": overwrite slot 1's rows with a trained marker via the
    // import_bank path (what Backend::checkpoint_adapters calls).
    let name = "lora.layers.0.q.a";
    let l = manifest.build.lora.max_adapters;
    let n = reg.bank_tensor(name).unwrap().element_count();
    let per = n / l;
    let mut bank: Vec<f32> = reg.bank_tensor(name).unwrap().as_f32().unwrap().to_vec();
    for (i, v) in bank[per..2 * per].iter_mut().enumerate() {
        *v = i as f32 * 0.5 + 1.0;
    }
    reg.import_bank(name, &bank).unwrap();
    let marker: Vec<f32> = bank[per..2 * per].to_vec();

    // Evict: the adapter parks on the host tier under its adapter name,
    // the slot is zeroed and freed.
    let key = reg.evict_to_host(1).unwrap();
    assert_eq!(key, "a1");
    assert!(reg.on_host(&key));
    assert_eq!(reg.host_len(), 1);
    assert_eq!(reg.resident_slot(&key), None);
    let rows = reg.bank_tensor(name).unwrap().as_f32().unwrap()[per..2 * per].to_vec();
    assert!(rows.iter().all(|&x| x == 0.0), "evicted slot must be zeroed");

    // Swap back in: lowest free slot (1) is reused, and the TRAINED rows —
    // not the attach-time payload — come back bit for bit.
    assert_eq!(reg.swap_in(&key).unwrap(), 1);
    assert_eq!(reg.host_len(), 0);
    assert_eq!(reg.resident_slot("a1"), Some(1));
    let back = reg.bank_tensor(name).unwrap().as_f32().unwrap()[per..2 * per].to_vec();
    assert_eq!(back, marker, "trained bank must survive the round trip bit-identically");

    // Slot-reuse golden after eviction: attach_auto takes the freed slot 0,
    // and the evicted adapter then lands in the next lowest free slot (2).
    let k0 = reg.evict_to_host(0).unwrap();
    assert_eq!(k0, "a0");
    let a2 = LoraAdapter::from_store(&store, &manifest, 2, "a2").unwrap();
    assert_eq!(
        reg.attach_auto("vm2", a2, SlotState::Inference).unwrap().slot,
        0,
        "attach_auto must reuse the evicted slot"
    );
    assert_eq!(reg.swap_in(&k0).unwrap(), 2);
    let got = reg.bank_tensor(name).unwrap().as_f32().unwrap()[2 * per..3 * per].to_vec();
    let want = store.tensor("adapter0.layers.0.q.a").unwrap();
    assert_eq!(
        got,
        want.as_f32().unwrap(),
        "relocated adapter must land bit-identical in its new slot"
    );
}

// ---------------------------------------------------------------------------
// Artifact-backed tier — skip-on-absent
// ---------------------------------------------------------------------------

#[test]
fn registry_rebuild_matches_python_bank_records() {
    // Same contract as the synthetic variant, but against the bank arrays
    // Python's aot.py wrote — catches Rust/Python layout drift.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_filtered(&dir, |_| false).unwrap();
    let manifest = rt.manifest.clone();
    let store = WeightStore::open(&dir, &manifest).unwrap();
    check_registry_rebuild(&manifest, &store);
}

fn golden_files(artifacts: &Path) -> Vec<PathBuf> {
    let dir = artifacts.join("golden");
    let mut out: Vec<PathBuf> = std::fs::read_dir(&dir)
        .expect("golden dir")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    out.sort();
    assert!(!out.is_empty(), "no golden files in {dir:?}");
    out
}

#[test]
fn golden_entries_reproduce_python_numbers() {
    let Some(dir) = artifacts_dir() else { return };
    let goldens = golden_files(&dir);
    let wanted: Vec<String> = goldens
        .iter()
        .map(|p| {
            let text = std::fs::read_to_string(p).unwrap();
            json::parse(&text).unwrap().req("entry").unwrap().as_str().unwrap().to_string()
        })
        .collect();
    let mut rt =
        Runtime::load_filtered(&dir, |n| wanted.iter().any(|w| w == n)).expect("runtime load");
    let store = WeightStore::open(&dir, &rt.manifest).expect("weights");

    for path in &goldens {
        let text = std::fs::read_to_string(path).unwrap();
        let g = json::parse(&text).unwrap();
        let entry = g.req("entry").unwrap().as_str().unwrap().to_string();
        let rtol = g.get("rtol").and_then(|r| r.as_f64().ok()).unwrap_or(2e-4);

        // Materialize inputs per the golden contract.
        let spec = rt.manifest.entry(&entry).unwrap().clone();
        let mut owned: Vec<HostTensor> = Vec::new();
        for (i, inp) in g.req("inputs").unwrap().as_arr().unwrap().iter().enumerate() {
            let ispec = &spec.inputs[i];
            let t = if let Some(r) = inp.get("ref") {
                let wname = r.as_str().unwrap().strip_prefix("weights:").unwrap().to_string();
                store.tensor(&wname).unwrap()
            } else if inp.get("zeros").is_some() {
                HostTensor::zeros(ispec)
            } else {
                match ispec.dtype {
                    DType::F32 => HostTensor::f32(
                        ispec.shape.clone(),
                        inp.req("data").unwrap().f32_vec().unwrap(),
                    )
                    .unwrap(),
                    DType::I32 => HostTensor::i32(
                        ispec.shape.clone(),
                        inp.req("data").unwrap().i32_vec().unwrap(),
                    )
                    .unwrap(),
                }
            };
            owned.push(t);
        }
        let args: Vec<Arg> = owned.iter().map(Arg::Host).collect();
        let (outs, _t) = rt.execute(&entry, &args, &[]).expect("execute");

        for want in g.req("outputs").unwrap().as_arr().unwrap() {
            let name = want.req("name").unwrap().as_str().unwrap();
            let data = want.req("data").unwrap().f32_vec().unwrap();
            let got = outs.get(name).unwrap_or_else(|_| panic!("{entry}: output {name}"));
            let gv = got.as_f32().unwrap();
            assert_eq!(gv.len(), data.len(), "{entry}.{name}: length");
            let mut worst = 0.0f32;
            for (a, b) in gv.iter().zip(&data) {
                let denom = b.abs().max(1.0);
                worst = worst.max((a - b).abs() / denom);
            }
            assert!(
                worst <= rtol as f32 * 10.0,
                "{entry}.{name}: rel err {worst} > {rtol}"
            );
        }
        println!("golden ok: {entry}");
    }
}

#[test]
fn weight_store_spec_sanity() {
    // Keep a TensorSpec construction compiling against the public API.
    let spec = TensorSpec { name: "x".into(), shape: vec![2], dtype: DType::F32 };
    assert_eq!(spec.element_count(), 2);
}
