//! Property-based tests on coordinator invariants (routing, batching, KV
//! state), driven by the in-tree prop harness over the sim backend — plus
//! the paged-KV/preemption suite (DESIGN.md §8): block-ledger conservation,
//! preemption determinism on the native backend, and the on-demand-vs-
//! worst-case burst comparison.
//!
//! Invariants mirrored from the paper's correctness argument:
//!  * every non-dropped request finishes with exactly min(max_new, ...) tokens;
//!  * adapters never cross: a request's rows are always routed to its slot;
//!  * KV accounting: no slot/block leaks, no double allocation or
//!    double free, ledger conserved across preempt/release/cancel;
//!  * trainer isolation: per-job token accounting is conserved;
//!  * a preempted-then-resumed request emits the identical token sequence
//!    an unpreempted run emits (recompute-on-resume is output-transparent);
//!  * chunked prefill is equally transparent (DESIGN.md §9): slicing a
//!    prompt across steps changes no output bit on the native backend, at
//!    any thread count — and the SLO-aware policy strictly beats FIFO on
//!    the long-prompt burst it exists for.

use std::collections::{BTreeMap, HashMap};

use loquetier::coordinator::{
    Coordinator, CoordinatorConfig, FinetuneJob, InferenceRequest, PolicyKind, TrainExample,
};
use loquetier::engine::{CostModel, SimBackend};
use loquetier::harness::{self, HarnessBuilder};
use loquetier::kvcache::CacheConfig;
use loquetier::metrics::SloSpec;
use loquetier::runtime::{BucketTable, ModelGeometry, UnifiedShape};
use loquetier::util::prop;
use loquetier::util::rng::Rng;

fn geometry() -> ModelGeometry {
    ModelGeometry {
        vocab_size: 128,
        hidden_size: 32,
        intermediate_size: 64,
        num_layers: 2,
        num_heads: 4,
        num_kv_heads: 2,
        head_dim: 8,
        rope_theta: 1e4,
        rms_eps: 1e-5,
        max_cache_len: 96,
        q_dim: 32,
        kv_dim: 16,
    }
}

fn buckets() -> BucketTable {
    BucketTable {
        prefill: vec![(4, 32)],
        decode: vec![8],
        train: vec![(2, 32)],
        unified: vec![UnifiedShape {
            ft_batch: 2,
            ft_seq: 32,
            pf_batch: 2,
            pf_seq: 32,
            dec_batch: 8,
        }],
    }
}

fn coordinator(slots: usize, blocks: usize) -> Coordinator {
    Coordinator::new(
        CoordinatorConfig { max_prompt_tokens: 32, drop_after_s: 1e9, ..Default::default() },
        CacheConfig {
            num_slots: slots,
            slot_capacity: 96,
            block_tokens: 16,
            total_blocks: blocks,
            num_layers: 2,
            token_elems: 16,
        },
    )
}

fn backend() -> SimBackend {
    SimBackend::new(geometry(), buckets(), CostModel::default())
}

fn drive(c: &mut Coordinator, be: &mut SimBackend, max_steps: usize) -> usize {
    let mut steps = 0;
    while !c.quiescent() && steps < max_steps {
        let out = c.step(be).unwrap();
        if out.idle {
            break;
        }
        steps += 1;
    }
    steps
}

#[test]
fn prop_every_request_completes_exactly() {
    prop::check("every request completes with exact token count", 40, |rng| {
        let mut c = coordinator(8, 48);
        let mut be = backend();
        let n = rng.range_usize(1, 24);
        let mut want: Vec<(u64, usize)> = Vec::new();
        for i in 0..n {
            let max_new = rng.range_usize(1, 12);
            let plen = rng.range_usize(1, 30);
            want.push((i as u64, max_new));
            c.submit(InferenceRequest {
                id: i as u64,
                adapter: rng.range(-1, 4) as i32,
                prompt: (0..plen as i32).collect(),
                max_new_tokens: max_new,
                eos_token: None,
                arrival_s: 0.0,
                slo: None,
            });
        }
        drive(&mut c, &mut be, 20_000);
        if !c.quiescent() {
            return Err("did not drain".into());
        }
        if c.traces.len() != n {
            return Err(format!("{} traces for {n} requests", c.traces.len()));
        }
        for t in &c.traces {
            if t.failed {
                return Err("unexpected failure".into());
            }
        }
        let mut got: Vec<usize> = c.traces.iter().map(|t| t.output_tokens).collect();
        got.sort_unstable();
        let mut expect: Vec<usize> = want.iter().map(|&(_, m)| m).collect();
        expect.sort_unstable();
        if got != expect {
            return Err(format!("token counts {got:?} != {expect:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_kv_never_leaks_or_double_books() {
    prop::check("kv slots+blocks return to zero; occupancy never exceeds cap", 40, |rng| {
        let mut c = coordinator(rng.range_usize(2, 9), rng.range_usize(12, 60));
        let mut be = backend();
        let n = rng.range_usize(1, 40);
        for i in 0..n {
            c.submit(InferenceRequest {
                id: i as u64,
                adapter: (i % 4) as i32,
                prompt: (0..rng.range(1, 30)).map(|x| x as i32).collect(),
                max_new_tokens: rng.range_usize(1, 10),
                eos_token: None,
                arrival_s: 0.0,
                slo: None,
            });
        }
        let mut steps = 0;
        while !c.quiescent() && steps < 50_000 {
            let st = c.kv.stats();
            if st.blocks_used > st.blocks_total {
                return Err("block over-booking".into());
            }
            if st.slots_used > st.slots_total {
                return Err("slot over-booking".into());
            }
            let out = c.step(&mut be).map_err(|e| e.to_string())?;
            if out.idle {
                break;
            }
            steps += 1;
        }
        let st = c.kv.stats();
        if st.slots_used != 0 || st.blocks_used != 0 {
            return Err(format!("leak: {} slots, {} blocks", st.slots_used, st.blocks_used));
        }
        Ok(())
    });
}

#[test]
fn prop_trainer_token_accounting_conserved() {
    prop::check("fine-tune + eval tokens equal dataset totals", 25, |rng| {
        let mut c = coordinator(8, 48);
        let mut be = backend();
        let n_jobs = rng.range_usize(1, 3);
        let mut want_train = 0u64;
        let mut want_eval = 0u64;
        for j in 0..n_jobs {
            let n_train = rng.range_usize(1, 10);
            let n_eval = rng.range_usize(0, 4);
            let epochs = rng.range_usize(1, 3);
            let len = rng.range_usize(4, 32);
            let ex = |_: usize| TrainExample {
                tokens: vec![1; len],
                labels: vec![1; len],
            };
            want_train += (n_train * len * epochs) as u64;
            want_eval += (n_eval * len * epochs) as u64;
            c.add_trainer(FinetuneJob {
                id: j as u64,
                adapter: (j % 4) as i32,
                train_set: (0..n_train).map(ex).collect(),
                eval_set: (0..n_eval).map(ex).collect(),
                epochs,
                per_device_batch: rng.range_usize(1, 3),
                grad_accum: rng.range_usize(1, 5),
                lr: 1e-3,
                eval_each_epoch: true,
            });
        }
        drive(&mut c, &mut be, 100_000);
        if !c.quiescent() {
            return Err("trainers did not finish".into());
        }
        if c.finetune_tokens() != want_train {
            return Err(format!("train tokens {} != {want_train}", c.finetune_tokens()));
        }
        if c.eval_tokens() != want_eval {
            return Err(format!("eval tokens {} != {want_eval}", c.eval_tokens()));
        }
        Ok(())
    });
}

#[test]
fn prop_mixed_load_drains_with_bounded_overflow() {
    // Unified load: inference + trainers together, random interleavings;
    // everything must drain and every trace must be terminal.
    prop::check("mixed unified load drains", 20, |rng: &mut Rng| {
        let mut c = coordinator(8, 60);
        let mut be = backend();
        for i in 0..rng.range_usize(1, 16) {
            c.submit(InferenceRequest {
                id: i as u64,
                adapter: rng.range(-1, 4) as i32,
                prompt: (0..rng.range(1, 30)).map(|x| x as i32).collect(),
                max_new_tokens: rng.range_usize(1, 8),
                eos_token: None,
                arrival_s: rng.f64() * 2.0,
                slo: None,
            });
        }
        let len = rng.range_usize(8, 32);
        c.add_trainer(FinetuneJob {
            id: 99,
            adapter: 3,
            train_set: (0..rng.range_usize(1, 8))
                .map(|_| TrainExample { tokens: vec![2; len], labels: vec![2; len] })
                .collect(),
            eval_set: vec![],
            epochs: rng.range_usize(1, 3),
            per_device_batch: 2,
            grad_accum: 2,
            lr: 1e-3,
            eval_each_epoch: false,
        });
        c.advance_clock(10.0); // all arrivals in the past
        drive(&mut c, &mut be, 100_000);
        if !c.quiescent() {
            return Err("mixed load did not drain".into());
        }
        for t in &c.traces {
            if !t.failed && t.finish_s.is_none() {
                return Err("non-terminal trace".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fifo_admission_no_starvation() {
    // With equal requests, completion order must roughly follow arrival
    // order: request k must not finish after request k + slots*4.
    prop::check("no starvation under FIFO admission", 15, |rng| {
        let mut c = coordinator(4, 32);
        let mut be = backend();
        let n = 20;
        for i in 0..n {
            c.submit(InferenceRequest {
                id: i as u64,
                adapter: 0,
                prompt: vec![1; 8],
                max_new_tokens: 4,
                eos_token: None,
                arrival_s: i as f64 * 0.01,
                slo: None,
            });
        }
        let _ = rng;
        c.advance_clock(1.0);
        let mut finish_order: Vec<u64> = Vec::new();
        let mut steps = 0;
        while !c.quiescent() && steps < 10_000 {
            let out = c.step(&mut be).unwrap();
            finish_order.extend(out.completed_requests.iter());
            if out.idle {
                break;
            }
            steps += 1;
        }
        for (pos, &id) in finish_order.iter().enumerate() {
            if (id as usize) > pos + 16 {
                return Err(format!("request {id} finished at position {pos}: starvation"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Paged KV + preempt-and-recompute (DESIGN.md §8)
// ---------------------------------------------------------------------------

#[test]
fn prop_block_ledger_conserved_under_preemption_and_cancel() {
    // Tight block pools force the preemption path; random mid-flight
    // cancels exercise release from every lifecycle state. The ledger
    // audit (blocks_used == sum of per-slot claims, len within claims, no
    // blocks on free slots) must hold after EVERY step, and drain to zero.
    prop::check("block ledger conserved across preempt/release/cancel", 25, |rng| {
        // Every request is individually feasible: worst case 24 + 16 = 40
        // tokens = 5 blocks at block_tokens 8, and the pool has >= 6.
        let mut c = Coordinator::new(
            CoordinatorConfig { max_prompt_tokens: 64, drop_after_s: 1e9, ..Default::default() },
            CacheConfig {
                num_slots: rng.range_usize(2, 9),
                slot_capacity: 96,
                block_tokens: 8,
                total_blocks: rng.range_usize(6, 20),
                num_layers: 2,
                token_elems: 16,
            },
        );
        let mut be = backend();
        let n = rng.range_usize(4, 24);
        for i in 0..n {
            c.submit(InferenceRequest {
                id: i as u64,
                adapter: rng.range(-1, 4) as i32,
                prompt: (0..rng.range(1, 24)).map(|x| x as i32).collect(),
                max_new_tokens: rng.range_usize(1, 16),
                eos_token: None,
                arrival_s: 0.0,
                slo: None,
            });
        }
        let mut live: Vec<u64> = (0..n as u64).collect();
        let mut steps = 0;
        while !c.quiescent() && steps < 50_000 {
            let out = c.step(&mut be).map_err(|e| e.to_string())?;
            c.kv.audit_ledger().map_err(|e| format!("step {steps}: {e}"))?;
            for id in &out.completed_requests {
                live.retain(|x| x != id);
            }
            // Occasionally cancel a random live request (client gone).
            if !live.is_empty() && rng.range_usize(0, 10) == 0 {
                let id = live[rng.range_usize(0, live.len())];
                c.cancel(id).map_err(|e| e.to_string())?;
                live.retain(|x| *x != id);
                c.kv.audit_ledger().map_err(|e| format!("cancel at {steps}: {e}"))?;
            }
            if out.idle {
                break;
            }
            steps += 1;
        }
        if !c.quiescent() {
            return Err(format!("did not drain in {steps} steps"));
        }
        let st = c.kv.stats();
        if st.slots_used != 0 || st.blocks_used != 0 {
            return Err(format!("leak: {} slots, {} blocks", st.slots_used, st.blocks_used));
        }
        c.kv.audit_ledger().map_err(|e| e.to_string())?;
        if c.traces.len() != n {
            return Err(format!("{} traces for {n} requests", c.traces.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_block_ledger_conserved_under_prefix_sharing_churn() {
    // The §14 extension of the conservation property: with the radix
    // prefix index on, requests share per-adapter system prefixes over a
    // TIGHT pool, so admissions attach to cached chains, eviction reclaims
    // unreferenced tails, preemption drops refs, and a co-running trainer
    // invalidates its adapter's subtree at every optimizer step. The
    // extended audit (blocks_used == kv claims + adapter pages + live
    // index nodes, refcounts exactly match live slots' chain references)
    // must hold after EVERY step and cancel, and the run must still drain.
    prop::check("block ledger + refcounts conserved under sharing churn", 25, |rng| {
        let mut c = Coordinator::new(
            CoordinatorConfig {
                max_prompt_tokens: 64,
                drop_after_s: 1e9,
                prefix_sharing: true,
                ..Default::default()
            },
            CacheConfig {
                num_slots: rng.range_usize(2, 9),
                slot_capacity: 96,
                block_tokens: 8,
                total_blocks: rng.range_usize(8, 20),
                num_layers: 2,
                token_elems: 16,
            },
        );
        let mut be = backend();
        let n = rng.range_usize(4, 24);
        for i in 0..n {
            // Per-adapter system prefix (3 blocks at block_tokens 8) + a
            // short per-request tail: same-adapter requests share radix
            // paths, cross-adapter ones never do.
            let adapter = rng.range(0, 4) as i32;
            let mut prompt: Vec<i32> = (0..24).map(|k| adapter * 31 + k).collect();
            prompt.extend((0..rng.range(1, 16)).map(|k| 1000 + i as i32 * 17 + k as i32));
            c.submit(InferenceRequest {
                id: i as u64,
                adapter,
                prompt,
                max_new_tokens: rng.range_usize(1, 16),
                eos_token: None,
                arrival_s: 0.0,
                slo: None,
            });
        }
        // A trainer on adapter 0: each optimizer step detaches adapter 0's
        // cached prefixes mid-churn (the §14 staleness rule).
        c.add_trainer(FinetuneJob {
            id: 99,
            adapter: 0,
            train_set: (0..rng.range_usize(2, 6))
                .map(|_| TrainExample { tokens: vec![2; 16], labels: vec![2; 16] })
                .collect(),
            eval_set: vec![],
            epochs: 1,
            per_device_batch: 2,
            grad_accum: 2,
            lr: 1e-3,
            eval_each_epoch: false,
        });
        let mut live: Vec<u64> = (0..n as u64).collect();
        let mut steps = 0;
        while !c.quiescent() && steps < 50_000 {
            let out = c.step(&mut be).map_err(|e| e.to_string())?;
            c.kv.audit_ledger().map_err(|e| format!("step {steps}: {e}"))?;
            for id in &out.completed_requests {
                live.retain(|x| x != id);
            }
            if !live.is_empty() && rng.range_usize(0, 10) == 0 {
                let id = live[rng.range_usize(0, live.len())];
                c.cancel(id).map_err(|e| e.to_string())?;
                live.retain(|x| *x != id);
                c.kv.audit_ledger().map_err(|e| format!("cancel at {steps}: {e}"))?;
            }
            if out.idle {
                break;
            }
            steps += 1;
        }
        if !c.quiescent() {
            return Err(format!("did not drain in {steps} steps"));
        }
        let st = c.kv.stats();
        // Drained: no slots, no sharer refs; the only remaining claims are
        // the (unreferenced, evictable-on-demand) index nodes themselves.
        if st.slots_used != 0 || st.kv_blocks_shared != 0 {
            return Err(format!(
                "leak: {} slots, {} shared blocks",
                st.slots_used, st.kv_blocks_shared
            ));
        }
        if st.blocks_used != st.prefix_blocks {
            return Err(format!(
                "leak: {} blocks used but only {} live index nodes",
                st.blocks_used, st.prefix_blocks
            ));
        }
        c.kv.audit_ledger().map_err(|e| e.to_string())?;
        if c.traces.len() != n {
            return Err(format!("{} traces for {n} requests", c.traces.len()));
        }
        Ok(())
    });
}

#[test]
fn burst_on_demand_paging_beats_worst_case_reservation() {
    // The acceptance scenario: a burst that head-of-line-blocks under
    // worst-case reservation (4 blocks each -> 3 concurrent) runs wider
    // under on-demand paging (1 prompt block each -> slot-limited 8),
    // completing strictly more requests in the same step budget with
    // strictly less reserved-but-unused capacity — and every preempted
    // request still streams exactly its final output.
    let buckets = BucketTable {
        prefill: vec![(8, 64)],
        decode: vec![16],
        train: vec![(2, 32)],
        unified: vec![UnifiedShape {
            ft_batch: 2,
            ft_seq: 32,
            pf_batch: 8,
            pf_seq: 64,
            dec_batch: 16,
        }],
    };
    let cache = CacheConfig {
        num_slots: 8,
        slot_capacity: 96,
        block_tokens: 16,
        total_blocks: 12,
        num_layers: 2,
        token_elems: 16,
    };
    let run = |worst_case: bool| {
        let mut c = Coordinator::new(
            CoordinatorConfig {
                max_prompt_tokens: 64,
                drop_after_s: 1e9,
                reserve_worst_case: worst_case,
                ..Default::default()
            },
            cache,
        );
        let mut be = SimBackend::new(geometry(), buckets.clone(), CostModel::default());
        for i in 0..16u64 {
            c.submit(InferenceRequest {
                id: i,
                adapter: (i % 4) as i32,
                prompt: (0..16).collect(),
                max_new_tokens: 48,
                eos_token: None,
                arrival_s: 0.0,
                slo: None,
            });
        }
        let mut emitted: HashMap<u64, Vec<i32>> = HashMap::new();
        let mut outputs: HashMap<u64, Vec<i32>> = HashMap::new();
        let mut max_active = 0usize;
        for _ in 0..200 {
            if c.quiescent() {
                break;
            }
            let out = c.step(&mut be).unwrap();
            c.kv.audit_ledger().unwrap();
            max_active = max_active.max(c.active_len());
            for &(id, t) in &out.emitted_tokens {
                emitted.entry(id).or_default().push(t);
            }
            for (id, toks) in out.completed_outputs {
                outputs.insert(id, toks);
            }
            if out.idle {
                break;
            }
        }
        (outputs, emitted, max_active, c.kv_frag_peak_tokens(), c.preempted_total())
    };

    let (done_wc, _, active_wc, frag_wc, preempt_wc) = run(true);
    let (done_od, emitted_od, active_od, frag_od, preempt_od) = run(false);

    assert_eq!(preempt_wc, 0, "worst-case reservation never preempts");
    assert!(preempt_od > 0, "the paged burst must exercise preemption");
    assert!(
        active_od > active_wc,
        "paging must admit strictly more concurrent requests ({active_od} vs {active_wc})"
    );
    assert!(
        frag_od < frag_wc,
        "tokens_reserved_unused must shrink under paging ({frag_od} vs {frag_wc})"
    );
    assert!(
        done_od.len() > done_wc.len(),
        "paging must complete strictly more requests in the same budget ({} vs {})",
        done_od.len(),
        done_wc.len()
    );
    // Exact output parity for preempted requests: the incremental stream
    // equals the final output, token for token.
    for (id, full) in &done_od {
        assert_eq!(full.len(), 48);
        assert_eq!(&emitted_od[id], full, "stream/output parity for request {id}");
    }
}

/// Drive a tiny serving-only workload over the REAL native backend and
/// return (per-request outputs, preemption count).
fn native_serve(total_blocks: usize, threads: usize) -> (BTreeMap<u64, Vec<i32>>, u64) {
    let (mut be, _reg, _manifest) =
        HarnessBuilder::new().seed(42).threads(threads).native_stack().unwrap();
    // Native geometry: 2 layers, token_elems = nkv * hd = 16, cache 160.
    // max_prompt_tokens = 16 < 8 + 24: resumed recompute contexts (up to
    // 31 tokens) exceed the admission bucket. Output transparency demands
    // the resume path prefill the FULL folded context anyway — if it
    // re-truncated to the bucket, the constrained run's post-resume
    // logits would diverge from the unconstrained run and the equality
    // assertions below would catch it.
    let mut c = Coordinator::new(
        CoordinatorConfig { max_prompt_tokens: 16, drop_after_s: 1e9, ..Default::default() },
        CacheConfig {
            num_slots: 6,
            slot_capacity: 160,
            block_tokens: 16,
            total_blocks,
            num_layers: 2,
            token_elems: 16,
        },
    );
    for i in 0..6u64 {
        c.submit(InferenceRequest {
            id: i,
            adapter: (i as i32 % 5) - 1, // -1 (base) and slots 0..3
            prompt: (0..8).map(|k| ((i as i32) * 31 + k * 7 + 3) % 512).collect(),
            max_new_tokens: 24,
            eos_token: None,
            arrival_s: 0.0,
            slo: None,
        });
    }
    let mut outputs: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
    let mut steps = 0;
    while !c.quiescent() && steps < 5_000 {
        let out = c.step(&mut be).unwrap();
        c.kv.audit_ledger().unwrap();
        for (id, toks) in out.completed_outputs {
            outputs.insert(id, toks);
        }
        if out.idle {
            break;
        }
        steps += 1;
    }
    assert!(c.quiescent(), "native serve drained (steps={steps})");
    assert_eq!(outputs.len(), 6);
    assert!(c.traces.iter().all(|t| !t.failed && t.output_tokens == 24));
    (outputs, c.preempted_total())
}

// ---------------------------------------------------------------------------
// Unified adapter+KV paging (DESIGN.md §10)
// ---------------------------------------------------------------------------

#[test]
fn prop_unified_ledger_conserved_under_adapter_paging_churn() {
    // Adapter A/B pages live in the SAME block ledger as KV. Random
    // multi-tenant churn under a tight residency budget — admissions,
    // evictions, swap-ins and policy prefetches all mutate the ledger —
    // must keep `audit_ledger` green after EVERY step, keep a training
    // adapter pinned resident for the trainer's whole lifetime, and drain
    // to an all-adapter (zero-KV) ledger.
    prop::check("adapter+KV unified ledger conserved under paging churn", 15, |rng| {
        let budget = rng.range_usize(2, 4);
        let num_slots = rng.range_usize(3, 6);
        // Sized so KV alone can never consume the whole pool (each request
        // is <= 3 blocks: 16-token prompt + 8 new at block_tokens 8) — the
        // paging *budget* is what's tight here (8 tenants, 2-3 resident),
        // so eviction/swap churn is constant but progress is always
        // possible.
        let total_blocks = num_slots * 3 + budget + 4;
        let mut c = Coordinator::new(
            CoordinatorConfig {
                max_prompt_tokens: 32,
                drop_after_s: 1e9,
                adapter_budget: budget,
                adapter_page_blocks: 1,
                adapter_paging: true,
                ..Default::default()
            },
            CacheConfig {
                num_slots,
                slot_capacity: 96,
                block_tokens: 8,
                total_blocks,
                num_layers: 2,
                token_elems: 16,
            },
        );
        let mut be = backend();
        // 8 tenants churning through a 2-3 slot residency budget.
        for a in 0..8 {
            c.register_adapter(a);
        }
        let n = rng.range_usize(6, 20);
        for i in 0..n {
            // The first four adapters are deterministic (0..3): together
            // with the pinned trainer (7) the working set always exceeds
            // the 2-3 slot budget, so eviction churn is guaranteed.
            let adapter = if i < 4 { i as i32 } else { rng.range(-1, 8) as i32 };
            c.submit(InferenceRequest {
                id: i as u64,
                adapter,
                prompt: (0..rng.range(1, 16)).map(|x| x as i32).collect(),
                max_new_tokens: rng.range_usize(1, 8),
                eos_token: None,
                arrival_s: 0.0,
                slo: None,
            });
        }
        let t_adapter = 7i32;
        let len = rng.range_usize(4, 16);
        c.add_trainer(FinetuneJob {
            id: 99,
            adapter: t_adapter,
            train_set: (0..rng.range_usize(2, 6))
                .map(|_| TrainExample { tokens: vec![2; len], labels: vec![2; len] })
                .collect(),
            eval_set: vec![],
            epochs: 1,
            per_device_batch: 1,
            grad_accum: 2,
            lr: 1e-3,
            eval_each_epoch: false,
        });
        let mut steps = 0;
        let mut saw_pin = false;
        while !c.quiescent() && steps < 50_000 {
            let out = c.step(&mut be).map_err(|e| e.to_string())?;
            c.kv.audit_ledger().map_err(|e| format!("step {steps}: {e}"))?;
            let st = c.kv.stats();
            if st.blocks_used > st.blocks_total {
                return Err("block over-booking".into());
            }
            // Pinned-while-training: once the trainer's adapter is pinned
            // it must be resident on every subsequent step.
            if c.adapter_pinned(t_adapter) {
                saw_pin = true;
                if !c.adapter_is_resident(t_adapter) {
                    return Err(format!("step {steps}: pinned adapter {t_adapter} not resident"));
                }
            }
            if out.idle {
                break;
            }
            steps += 1;
        }
        if !c.quiescent() {
            return Err(format!("did not drain in {steps} steps"));
        }
        if !saw_pin {
            return Err("trainer adapter was never pinned".into());
        }
        if !c.adapter_pinned(t_adapter) {
            return Err("training pin must outlive the job (until checkpoint/unpin)".into());
        }
        c.kv.audit_ledger().map_err(|e| e.to_string())?;
        let st = c.kv.stats();
        // KV fully released; the only blocks still held are the resident
        // adapters' pages — and they match the pager's residency exactly.
        if st.slots_used != 0 {
            return Err(format!("leak: {} slots", st.slots_used));
        }
        if st.blocks_used != st.adapter_blocks {
            return Err(format!(
                "KV leak: {} used vs {} adapter blocks",
                st.blocks_used, st.adapter_blocks
            ));
        }
        if st.adapters_resident != c.adapter_resident() {
            return Err(format!(
                "ledger residency {} != pager residency {}",
                st.adapters_resident,
                c.adapter_resident()
            ));
        }
        if c.adapter_swaps() == 0 {
            return Err("paging churn must actually swap".into());
        }
        // Releasing the training pin makes the adapter evictable again —
        // the checkpoint path's unpin contract.
        c.unpin_adapter(t_adapter);
        if c.adapter_pinned(t_adapter) {
            return Err("unpin_adapter must clear the pin".into());
        }
        Ok(())
    });
}

#[test]
fn zipfian_paged_adapters_beat_fixed_slot_baseline() {
    // ISSUE 6 acceptance: 1000 Zipfian tenants through a 16-adapter
    // residency budget. The scenario is single-sourced in
    // `harness::zipf_paging_outcome` — the figures bench writes the SAME
    // two runs to BENCH_FIGURES.json and CI jq-gates the same strict
    // inequality, so test and figure can never drift apart. The paged run
    // pays for every swap (the cost model's `adapter_swap_s` charges into
    // the clock) and still strictly beats the fixed-slot baseline, which
    // permanently parks the first 16 adapters touched and fails everyone
    // else's admissions.
    let cost = CostModel::default();
    let fixed = harness::zipf_paging_outcome(&cost, false);
    let paged = harness::zipf_paging_outcome(&cost, true);

    assert_eq!(fixed.swaps, 0, "fixed-slot mode never swaps");
    assert!(paged.swaps > 0, "the Zipf tail must force swap traffic");
    assert!(
        paged.resident <= harness::ZIPF_RESIDENT_BUDGET,
        "steady-state residency within budget ({} > {})",
        paged.resident,
        harness::ZIPF_RESIDENT_BUDGET
    );
    assert_eq!(
        paged.resident + paged.host,
        harness::ZIPF_ADAPTERS,
        "every registered tenant is accounted for across the two tiers"
    );
    assert!(
        paged.completed > fixed.completed,
        "paged must complete strictly more requests ({} !> {})",
        paged.completed,
        fixed.completed
    );
    assert!(
        paged.attainment > fixed.attainment,
        "paged must strictly beat fixed-slot on attainment ({} !> {})",
        paged.attainment,
        fixed.attainment
    );
}

// ---------------------------------------------------------------------------
// Scheduler policy layer (DESIGN.md §9)
// ---------------------------------------------------------------------------

#[test]
fn slo_aware_chunked_prefill_beats_fifo_on_burst() {
    // The acceptance workload lives in `harness::long_prompt_burst` —
    // single-sourced with the figures bench (which gates CI on the same
    // strict inequality), so the two assertions can never drift apart.
    // `harness::policy_attainment` additionally asserts the scheduler's
    // live attainment tracker equals the post-hoc trace report.
    let cost = CostModel::default();
    let (fifo, fifo_done) =
        harness::policy_attainment(&cost, PolicyKind::Fifo, harness::long_prompt_burst());
    let (slo, slo_done) =
        harness::policy_attainment(&cost, PolicyKind::SloAware, harness::long_prompt_burst());
    assert_eq!(fifo_done, 32, "every request completes under FIFO");
    assert_eq!(slo_done, 32, "every request completes under SLO-aware");
    assert!(
        slo > fifo,
        "SLO-aware chunked prefill must strictly beat FIFO on the burst ({slo} !> {fifo})"
    );
    assert!(slo >= 0.9, "chunked prefill must hold the burst's SLO ({slo})");
}

/// Chunked-prefill output transparency on REAL numerics: splitting a
/// prompt's prefill across steps must not change one bit of any stream's
/// output (per-row math is independent of launch composition — DESIGN.md
/// §7 — and chunk k attends over chunks 0..k through the KV arena with
/// correct RoPE offsets), nor any trainer loss (micro-batches of one walk
/// the dataset in order regardless of step pacing). Mirrors the PR-4
/// preemption-transparency test, for chunks instead of preemptions.
fn native_chunked_serve(
    chunk_tokens: usize,
    threads: usize,
) -> (BTreeMap<u64, Vec<i32>>, Vec<f32>, usize) {
    let (mut be, _reg, _manifest) =
        HarnessBuilder::new().seed(42).threads(threads).native_stack().unwrap();
    let mut c = Coordinator::new(
        CoordinatorConfig {
            policy: PolicyKind::SloAware,
            prefill_chunk_tokens: chunk_tokens,
            max_prompt_tokens: 16,
            drop_after_s: 1e9,
            // Effectively-infinite deadlines: the chunking is what is
            // under test, not headroom throttling (which may differ
            // between pacings without affecting any output bit).
            slo: SloSpec {
                max_waiting_s: 1e9,
                mean_decode_latency_s: 1e9,
                max_decode_latency_s: 1e9,
            },
            ..Default::default()
        },
        CacheConfig {
            num_slots: 8,
            slot_capacity: 160,
            block_tokens: 16,
            total_blocks: 80,
            num_layers: 2,
            token_elems: 16,
        },
    );
    for i in 0..6u64 {
        c.submit(InferenceRequest {
            id: i,
            // Adapters -1..2 only: the trainer owns slot 3, so optimizer
            // timing differences can never touch a served row.
            adapter: (i as i32 % 4) - 1,
            prompt: (0..12).map(|k| ((i as i32) * 31 + k * 7 + 3) % 512).collect(),
            max_new_tokens: 8,
            eos_token: None,
            arrival_s: 0.0,
            slo: None,
        });
    }
    c.add_trainer(FinetuneJob {
        id: 9,
        adapter: 3,
        train_set: (0..4)
            .map(|i| TrainExample {
                tokens: (0..12).map(|k| ((i * 13 + k * 3 + 1) as i32) % 512).collect(),
                labels: (0..12).map(|k| ((i * 13 + k * 3 + 1) as i32) % 512).collect(),
            })
            .collect(),
        eval_set: vec![],
        epochs: 1,
        // Batch-of-one micro-steps: the (example, optimizer) sequence is
        // identical under any pacing, so losses compare bitwise.
        per_device_batch: 1,
        grad_accum: 2,
        lr: 1e-3,
        eval_each_epoch: false,
    });
    let mut outputs: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
    let mut prefill_slices = 0usize;
    let mut steps = 0;
    while !c.quiescent() && steps < 5_000 {
        let out = c.step(&mut be).unwrap();
        c.kv.audit_ledger().unwrap();
        prefill_slices += out.prefilled_seqs;
        for (id, toks) in out.completed_outputs {
            outputs.insert(id, toks);
        }
        if out.idle {
            break;
        }
        steps += 1;
    }
    assert!(c.quiescent(), "chunked serve drained (steps={steps})");
    assert_eq!(outputs.len(), 6);
    assert!(c.traces.iter().all(|t| !t.failed && t.output_tokens == 8));
    (outputs, c.trainers()[0].losses.clone(), prefill_slices)
}

#[test]
fn native_chunked_prefill_is_output_transparent_and_thread_invariant() {
    // chunk 5 over 12-token prompts: three slices each (5 + 5 + 2).
    let (chunked_t1, losses_c1, slices_c) = native_chunked_serve(5, 1);
    let (unchunked, losses_u, slices_u) = native_chunked_serve(0, 1);
    assert_eq!(slices_u, 6, "chunk 0 = one whole-prompt slice per request");
    assert_eq!(slices_c, 18, "chunk 5 must split every 12-token prompt in three");
    assert_eq!(
        chunked_t1, unchunked,
        "chunked vs unchunked prefill must be bitwise identical per stream"
    );
    assert_eq!(losses_c1, losses_u, "trainer losses must be bitwise identical");

    let (chunked_t4, losses_c4, _) = native_chunked_serve(5, 4);
    assert_eq!(chunked_t1, chunked_t4, "threads 1 vs 4 must be bitwise identical");
    assert_eq!(losses_c1, losses_c4, "losses thread-invariant too");
}

#[test]
fn native_preemption_is_output_transparent_and_thread_invariant() {
    // Recompute-on-resume determinism on REAL numerics: a 7-block pool
    // forces preemption (6 streams want 2 blocks each), a 60-block pool
    // never preempts. Per-row math is independent of batch composition
    // and the recompute prefill rebuilds the identical KV, so the token
    // streams must match exactly — and, via the PARTITION-ONLY rule
    // (DESIGN.md §7), be bitwise identical across thread counts.
    let (constrained_t1, preempted) = native_serve(7, 1);
    assert!(preempted > 0, "7-block pool must preempt");

    let (constrained_t4, preempted_t4) = native_serve(7, 4);
    assert_eq!(
        constrained_t1, constrained_t4,
        "threads=1 vs threads=4 must be bitwise identical"
    );
    assert_eq!(preempted, preempted_t4, "scheduling is thread-invariant too");

    let (unconstrained, unpreempted) = native_serve(60, 1);
    assert_eq!(unpreempted, 0, "60-block pool must not preempt");
    assert_eq!(
        constrained_t1, unconstrained,
        "preempt-and-recompute must not change any request's output"
    );
}
