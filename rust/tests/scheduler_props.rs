//! Property-based tests on coordinator invariants (routing, batching, KV
//! state), driven by the in-tree prop harness over the sim backend.
//!
//! Invariants mirrored from the paper's correctness argument:
//!  * every non-dropped request finishes with exactly min(max_new, ...) tokens;
//!  * adapters never cross: a request's rows are always routed to its slot;
//!  * KV accounting: no slot/block leaks, no double allocation, tile-aligned
//!    segment formation;
//!  * trainer isolation: per-job token accounting is conserved.

use loquetier::coordinator::{
    Coordinator, CoordinatorConfig, FinetuneJob, InferenceRequest, TrainExample,
};
use loquetier::engine::{CostModel, SimBackend};
use loquetier::kvcache::CacheConfig;
use loquetier::runtime::{BucketTable, ModelGeometry, UnifiedShape};
use loquetier::util::prop;
use loquetier::util::rng::Rng;

fn geometry() -> ModelGeometry {
    ModelGeometry {
        vocab_size: 128,
        hidden_size: 32,
        intermediate_size: 64,
        num_layers: 2,
        num_heads: 4,
        num_kv_heads: 2,
        head_dim: 8,
        rope_theta: 1e4,
        rms_eps: 1e-5,
        max_cache_len: 96,
        q_dim: 32,
        kv_dim: 16,
    }
}

fn buckets() -> BucketTable {
    BucketTable {
        prefill: vec![(4, 32)],
        decode: vec![8],
        train: vec![(2, 32)],
        unified: vec![UnifiedShape {
            ft_batch: 2,
            ft_seq: 32,
            pf_batch: 2,
            pf_seq: 32,
            dec_batch: 8,
        }],
    }
}

fn coordinator(slots: usize, blocks: usize) -> Coordinator {
    Coordinator::new(
        CoordinatorConfig { max_prompt_tokens: 32, drop_after_s: 1e9, ..Default::default() },
        CacheConfig {
            num_slots: slots,
            slot_capacity: 96,
            block_tokens: 16,
            total_blocks: blocks,
            num_layers: 2,
            token_elems: 16,
        },
    )
}

fn backend() -> SimBackend {
    SimBackend::new(geometry(), buckets(), CostModel::default())
}

fn drive(c: &mut Coordinator, be: &mut SimBackend, max_steps: usize) -> usize {
    let mut steps = 0;
    while !c.quiescent() && steps < max_steps {
        let out = c.step(be).unwrap();
        if out.idle {
            break;
        }
        steps += 1;
    }
    steps
}

#[test]
fn prop_every_request_completes_exactly() {
    prop::check("every request completes with exact token count", 40, |rng| {
        let mut c = coordinator(8, 48);
        let mut be = backend();
        let n = rng.range_usize(1, 24);
        let mut want: Vec<(u64, usize)> = Vec::new();
        for i in 0..n {
            let max_new = rng.range_usize(1, 12);
            let plen = rng.range_usize(1, 30);
            want.push((i as u64, max_new));
            c.submit(InferenceRequest {
                id: i as u64,
                adapter: rng.range(-1, 4) as i32,
                prompt: (0..plen as i32).collect(),
                max_new_tokens: max_new,
                eos_token: None,
                arrival_s: 0.0,
            });
        }
        drive(&mut c, &mut be, 20_000);
        if !c.quiescent() {
            return Err("did not drain".into());
        }
        if c.traces.len() != n {
            return Err(format!("{} traces for {n} requests", c.traces.len()));
        }
        for t in &c.traces {
            if t.failed {
                return Err("unexpected failure".into());
            }
        }
        let mut got: Vec<usize> = c.traces.iter().map(|t| t.output_tokens).collect();
        got.sort_unstable();
        let mut expect: Vec<usize> = want.iter().map(|&(_, m)| m).collect();
        expect.sort_unstable();
        if got != expect {
            return Err(format!("token counts {got:?} != {expect:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_kv_never_leaks_or_double_books() {
    prop::check("kv slots+blocks return to zero; occupancy never exceeds cap", 40, |rng| {
        let mut c = coordinator(rng.range_usize(2, 9), rng.range_usize(12, 60));
        let mut be = backend();
        let n = rng.range_usize(1, 40);
        for i in 0..n {
            c.submit(InferenceRequest {
                id: i as u64,
                adapter: (i % 4) as i32,
                prompt: (0..rng.range(1, 30)).map(|x| x as i32).collect(),
                max_new_tokens: rng.range_usize(1, 10),
                eos_token: None,
                arrival_s: 0.0,
            });
        }
        let mut steps = 0;
        while !c.quiescent() && steps < 50_000 {
            let st = c.kv.stats();
            if st.blocks_used > st.blocks_total {
                return Err("block over-booking".into());
            }
            if st.slots_used > st.slots_total {
                return Err("slot over-booking".into());
            }
            let out = c.step(&mut be).map_err(|e| e.to_string())?;
            if out.idle {
                break;
            }
            steps += 1;
        }
        let st = c.kv.stats();
        if st.slots_used != 0 || st.blocks_used != 0 {
            return Err(format!("leak: {} slots, {} blocks", st.slots_used, st.blocks_used));
        }
        Ok(())
    });
}

#[test]
fn prop_trainer_token_accounting_conserved() {
    prop::check("fine-tune + eval tokens equal dataset totals", 25, |rng| {
        let mut c = coordinator(8, 48);
        let mut be = backend();
        let n_jobs = rng.range_usize(1, 3);
        let mut want_train = 0u64;
        let mut want_eval = 0u64;
        for j in 0..n_jobs {
            let n_train = rng.range_usize(1, 10);
            let n_eval = rng.range_usize(0, 4);
            let epochs = rng.range_usize(1, 3);
            let len = rng.range_usize(4, 32);
            let ex = |_: usize| TrainExample {
                tokens: vec![1; len],
                labels: vec![1; len],
            };
            want_train += (n_train * len * epochs) as u64;
            want_eval += (n_eval * len * epochs) as u64;
            c.add_trainer(FinetuneJob {
                id: j as u64,
                adapter: (j % 4) as i32,
                train_set: (0..n_train).map(ex).collect(),
                eval_set: (0..n_eval).map(ex).collect(),
                epochs,
                per_device_batch: rng.range_usize(1, 3),
                grad_accum: rng.range_usize(1, 5),
                lr: 1e-3,
                eval_each_epoch: true,
            });
        }
        drive(&mut c, &mut be, 100_000);
        if !c.quiescent() {
            return Err("trainers did not finish".into());
        }
        if c.finetune_tokens() != want_train {
            return Err(format!("train tokens {} != {want_train}", c.finetune_tokens()));
        }
        if c.eval_tokens() != want_eval {
            return Err(format!("eval tokens {} != {want_eval}", c.eval_tokens()));
        }
        Ok(())
    });
}

#[test]
fn prop_mixed_load_drains_with_bounded_overflow() {
    // Unified load: inference + trainers together, random interleavings;
    // everything must drain and every trace must be terminal.
    prop::check("mixed unified load drains", 20, |rng: &mut Rng| {
        let mut c = coordinator(8, 60);
        let mut be = backend();
        for i in 0..rng.range_usize(1, 16) {
            c.submit(InferenceRequest {
                id: i as u64,
                adapter: rng.range(-1, 4) as i32,
                prompt: (0..rng.range(1, 30)).map(|x| x as i32).collect(),
                max_new_tokens: rng.range_usize(1, 8),
                eos_token: None,
                arrival_s: rng.f64() * 2.0,
            });
        }
        let len = rng.range_usize(8, 32);
        c.add_trainer(FinetuneJob {
            id: 99,
            adapter: 3,
            train_set: (0..rng.range_usize(1, 8))
                .map(|_| TrainExample { tokens: vec![2; len], labels: vec![2; len] })
                .collect(),
            eval_set: vec![],
            epochs: rng.range_usize(1, 3),
            per_device_batch: 2,
            grad_accum: 2,
            lr: 1e-3,
            eval_each_epoch: false,
        });
        c.advance_clock(10.0); // all arrivals in the past
        drive(&mut c, &mut be, 100_000);
        if !c.quiescent() {
            return Err("mixed load did not drain".into());
        }
        for t in &c.traces {
            if !t.failed && t.finish_s.is_none() {
                return Err("non-terminal trace".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fifo_admission_no_starvation() {
    // With equal requests, completion order must roughly follow arrival
    // order: request k must not finish after request k + slots*4.
    prop::check("no starvation under FIFO admission", 15, |rng| {
        let mut c = coordinator(4, 32);
        let mut be = backend();
        let n = 20;
        for i in 0..n {
            c.submit(InferenceRequest {
                id: i as u64,
                adapter: 0,
                prompt: vec![1; 8],
                max_new_tokens: 4,
                eos_token: None,
                arrival_s: i as f64 * 0.01,
            });
        }
        let _ = rng;
        c.advance_clock(1.0);
        let mut finish_order: Vec<u64> = Vec::new();
        let mut steps = 0;
        while !c.quiescent() && steps < 10_000 {
            let out = c.step(&mut be).unwrap();
            finish_order.extend(out.completed_requests.iter());
            if out.idle {
                break;
            }
            steps += 1;
        }
        for (pos, &id) in finish_order.iter().enumerate() {
            if (id as usize) > pos + 16 {
                return Err(format!("request {id} finished at position {pos}: starvation"));
            }
        }
        Ok(())
    });
}
