//! Frontend protocol integration: a real TCP loopback against
//! `serve_blocking`, with a stub engine loop answering from a thread —
//! exercises parsing, dispatch, reply framing and stats, end to end.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use loquetier::server::{serve_blocking, Frontend};
use loquetier::util::json;

fn start_server() -> (std::net::SocketAddr, std::sync::Arc<Frontend>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    let (frontend, jobs_rx) = Frontend::new();

    // Stub engine: echo the prompt tokens back, reversed, after a tick.
    std::thread::spawn(move || {
        while let Ok(job) = jobs_rx.recv() {
            let mut toks = job.request.prompt.clone();
            toks.reverse();
            toks.truncate(job.request.max_new_tokens);
            std::thread::sleep(Duration::from_millis(5));
            let _ = job.reply.send((toks, 0.005));
        }
    });

    let fe = frontend.clone();
    std::thread::spawn(move || {
        let _ = serve_blocking(
            listener,
            fe,
            |text| text.bytes().map(|b| b as i32).collect(),
            |ids| ids.iter().map(|&t| (t as u8) as char).collect(),
            |name| if name == Some("vm1") { 1 } else { -1 },
        );
    });
    (addr, frontend)
}

fn roundtrip(stream: &mut TcpStream, msg: &str) -> json::Json {
    stream.write_all(msg.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    json::parse(line.trim()).unwrap()
}

#[test]
fn generate_roundtrip_over_tcp() {
    let (addr, _fe) = start_server();
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();

    let reply = roundtrip(
        &mut stream,
        r#"{"op":"generate","prompt":"abc","model":"vm1","max_new_tokens":8}"#,
    );
    assert!(reply.get("error").is_none(), "{reply:?}");
    let text = reply.get("text").unwrap().as_str().unwrap();
    assert_eq!(text, "cba", "stub engine reverses the prompt");
    assert!(reply.get("latency_s").unwrap().as_f64().unwrap() >= 0.005);
}

#[test]
fn stats_and_errors_share_the_connection() {
    let (addr, fe) = start_server();
    {
        let mut s = fe.stats.lock().unwrap();
        s.queued = 3;
        s.decode_tokens = 42;
    }
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();

    let stats = roundtrip(&mut stream, r#"{"op":"stats"}"#);
    assert_eq!(stats.get("queued").unwrap().as_usize().unwrap(), 3);
    assert_eq!(stats.get("decode_tokens").unwrap().as_usize().unwrap(), 42);

    // A malformed request must produce an error object, not a hangup...
    let err = roundtrip(&mut stream, r#"{"op":"nope"}"#);
    assert!(err.get("error").is_some());

    // ...and the connection stays usable afterwards.
    let reply = roundtrip(
        &mut stream,
        r#"{"op":"generate","prompt":"xy","max_new_tokens":4}"#,
    );
    assert_eq!(reply.get("text").unwrap().as_str().unwrap(), "yx");
}

#[test]
fn concurrent_clients_are_served() {
    let (addr, _fe) = start_server();
    let handles: Vec<_> = (0..8)
        .map(|i| {
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
                let prompt = format!("p{i}");
                let reply = roundtrip(
                    &mut stream,
                    &format!(r#"{{"op":"generate","prompt":"{prompt}","max_new_tokens":4}}"#),
                );
                let text = reply.get("text").unwrap().as_str().unwrap().to_string();
                let mut want: Vec<char> = prompt.chars().collect();
                want.reverse();
                assert_eq!(text, want.into_iter().collect::<String>());
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}
