//! Frontend protocol integration: a real TCP loopback against
//! `serve_blocking` with the REAL `engine_loop` (coordinator + SimBackend +
//! adapter directory) answering from a thread — exercises parsing,
//! dispatch, adapter lifecycle, streaming, admission control, per-adapter
//! stats and graceful drain, end to end over the wire.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use loquetier::coordinator::{Coordinator, CoordinatorConfig};
use loquetier::engine::{CostModel, SimBackend};
use loquetier::kvcache::CacheConfig;
use loquetier::runtime::{BucketTable, ModelGeometry, UnifiedShape};
use loquetier::server::{
    engine_loop, serve_blocking, AdmissionConfig, AdapterSource, ControlMsg, ControlOp,
    ControlReply, EngineMsg, Frontend, GenerateJob, StaticDirectory, TokenEvent,
};
use loquetier::util::json;

fn geometry() -> ModelGeometry {
    ModelGeometry {
        vocab_size: 128,
        hidden_size: 32,
        intermediate_size: 64,
        num_layers: 2,
        num_heads: 4,
        num_kv_heads: 2,
        head_dim: 8,
        rope_theta: 1e4,
        rms_eps: 1e-5,
        max_cache_len: 96,
        q_dim: 32,
        kv_dim: 16,
    }
}

fn buckets() -> BucketTable {
    BucketTable {
        prefill: vec![(4, 32)],
        decode: vec![8],
        train: vec![(2, 32)],
        unified: vec![UnifiedShape { ft_batch: 2, ft_seq: 32, pf_batch: 2, pf_seq: 32, dec_batch: 8 }],
    }
}

fn cache_cfg() -> CacheConfig {
    CacheConfig {
        num_slots: 8,
        slot_capacity: 96,
        block_tokens: 16,
        total_blocks: 48,
        num_layers: 2,
        token_elems: 16,
    }
}

fn spawn_engine(admission: AdmissionConfig) -> Arc<Frontend> {
    let (frontend, rx) = Frontend::new(admission);
    let fe = frontend.clone();
    std::thread::spawn(move || {
        let mut coord = Coordinator::new(
            CoordinatorConfig { max_prompt_tokens: 32, ..Default::default() },
            cache_cfg(),
        );
        let mut be = SimBackend::new(geometry(), buckets(), CostModel::default());
        let mut dir = StaticDirectory::new(4, 8);
        let _ = engine_loop(&mut coord, &mut be, &mut dir, &rx, &fe);
    });
    frontend
}

/// Real engine + real TCP listener; byte-level tokenizer stubs.
fn start_server(admission: AdmissionConfig) -> (std::net::SocketAddr, Arc<Frontend>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    let frontend = spawn_engine(admission);
    let fe = frontend.clone();
    std::thread::spawn(move || {
        let _ = serve_blocking(
            listener,
            fe,
            |text| text.bytes().map(|b| b as i32).collect(),
            |ids| ids.iter().map(|&t| (t as u8) as char).collect(),
        );
    });
    (addr, frontend)
}

fn connect(addr: std::net::SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

fn send_line(stream: &mut TcpStream, msg: &str) {
    stream.write_all(msg.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
}

fn read_frame(reader: &mut BufReader<TcpStream>) -> json::Json {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    json::parse(line.trim()).unwrap_or_else(|e| panic!("bad frame {line:?}: {e}"))
}

fn roundtrip(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, msg: &str) -> json::Json {
    send_line(stream, msg);
    read_frame(reader)
}

#[test]
fn adapter_lifecycle_with_streamed_generation() {
    let (addr, _fe) = start_server(AdmissionConfig::default());
    let (mut stream, mut reader) = connect(addr);

    // Empty registry to start with.
    let r = roundtrip(&mut stream, &mut reader, r#"{"op":"list_adapters"}"#);
    assert_eq!(r.get("adapters").unwrap().as_arr().unwrap().len(), 0);

    // Unknown model refused (and counted against the tenant).
    let r = roundtrip(
        &mut stream,
        &mut reader,
        r#"{"op":"generate","prompt":"abc","model":"tenant0","max_new_tokens":4}"#,
    );
    assert!(r.get("error").unwrap().as_str().unwrap().contains("unknown model"), "{r:?}");
    assert_eq!(r.get("err").unwrap().as_str().unwrap(), "bad_request", "{r:?}");
    assert_eq!(r.get("code").unwrap().as_usize().unwrap(), 400);

    // Hot-load over the wire.
    let r = roundtrip(&mut stream, &mut reader, r#"{"op":"load_adapter","name":"tenant0"}"#);
    assert_eq!(r.get("ok").unwrap().as_bool().unwrap(), true, "{r:?}");
    assert_eq!(r.get("slot").unwrap().as_usize().unwrap(), 0);
    let r = roundtrip(&mut stream, &mut reader, r#"{"op":"list_adapters"}"#);
    let ads = r.get("adapters").unwrap().as_arr().unwrap();
    assert_eq!(ads.len(), 1);
    assert_eq!(ads[0].get("name").unwrap().as_str().unwrap(), "tenant0");

    // Streamed generation through the freshly loaded adapter: one frame per
    // token with contiguous 0-based indexes, then a terminal done frame
    // whose token list equals the streamed sequence.
    send_line(
        &mut stream,
        r#"{"op":"generate","prompt":"abcd","model":"tenant0","max_new_tokens":6,"stream":true}"#,
    );
    let mut streamed: Vec<i64> = Vec::new();
    let mut streamed_text = String::new();
    let done = loop {
        let f = read_frame(&mut reader);
        assert!(f.get("error").is_none(), "{f:?}");
        if f.get("done").is_some() {
            break f;
        }
        let idx = f.get("index").unwrap().as_usize().unwrap();
        assert_eq!(idx, streamed.len(), "frames arrive in order");
        streamed.push(f.get("token").unwrap().as_f64().unwrap() as i64);
        streamed_text.push_str(f.get("text").unwrap().as_str().unwrap());
    };
    assert_eq!(streamed.len(), 6);
    let final_tokens: Vec<i64> = done
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_f64().unwrap() as i64)
        .collect();
    assert_eq!(final_tokens, streamed, "stream equals final output");
    assert_eq!(done.get("text").unwrap().as_str().unwrap(), streamed_text);
    assert!(done.get("latency_s").unwrap().as_f64().unwrap() >= 0.0);

    // Stats now carry per-adapter counters for the tenant.
    let s = roundtrip(&mut stream, &mut reader, r#"{"op":"stats"}"#);
    let per = s.get("per_adapter").unwrap();
    let t0 = per.get("tenant0").unwrap();
    assert_eq!(t0.get("submitted").unwrap().as_usize().unwrap(), 1);
    assert_eq!(t0.get("completed").unwrap().as_usize().unwrap(), 1);
    assert_eq!(t0.get("decode_tokens").unwrap().as_usize().unwrap(), 6);
    assert_eq!(s.get("loaded_adapters").unwrap().as_usize().unwrap(), 1);

    // Hot-unload; the name stops resolving but its counters remain visible.
    let r = roundtrip(&mut stream, &mut reader, r#"{"op":"unload_adapter","name":"tenant0"}"#);
    assert_eq!(r.get("ok").unwrap().as_bool().unwrap(), true, "{r:?}");
    let r = roundtrip(
        &mut stream,
        &mut reader,
        r#"{"op":"generate","prompt":"abc","model":"tenant0","max_new_tokens":2}"#,
    );
    assert!(r.get("error").unwrap().as_str().unwrap().contains("unknown model"));
    let s = roundtrip(&mut stream, &mut reader, r#"{"op":"stats"}"#);
    assert_eq!(s.get("loaded_adapters").unwrap().as_usize().unwrap(), 0);
    let t0 = s.get("per_adapter").unwrap().get("tenant0").unwrap();
    assert_eq!(t0.get("completed").unwrap().as_usize().unwrap(), 1, "history survives unload");
}

#[test]
fn nonstream_generate_roundtrip_and_malformed_frames() {
    let (addr, _fe) = start_server(AdmissionConfig::default());
    let (mut stream, mut reader) = connect(addr);

    // Base-model generation (no "model" key) completes with a single frame.
    let r = roundtrip(
        &mut stream,
        &mut reader,
        r#"{"op":"generate","prompt":"xy","max_new_tokens":4}"#,
    );
    assert!(r.get("error").is_none(), "{r:?}");
    assert_eq!(r.get("tokens").unwrap().as_arr().unwrap().len(), 4);
    assert!(r.get("done").is_none(), "non-streaming reply has no done marker");

    // A malformed request must produce an error object, not a hangup...
    let err = roundtrip(&mut stream, &mut reader, r#"{"op":"nope"}"#);
    assert!(err.get("error").is_some());
    assert_eq!(err.get("code").unwrap().as_usize().unwrap(), 400);
    let err = roundtrip(&mut stream, &mut reader, "not json at all");
    assert!(err.get("error").is_some());

    // A request whose worst-case KV need can never fit (3 + 95 > the
    // 96-token slot capacity) must be refused up front, not left to
    // head-of-line-block the queue forever...
    let r = roundtrip(
        &mut stream,
        &mut reader,
        r#"{"op":"generate","prompt":"abc","max_new_tokens":95}"#,
    );
    assert!(r.get("error").unwrap().as_str().unwrap().contains("exceeds capacity"), "{r:?}");

    // ...and an empty prompt is refused instead of erroring the engine.
    let r = roundtrip(&mut stream, &mut reader, r#"{"op":"generate","prompt":""}"#);
    assert!(r.get("error").unwrap().as_str().unwrap().contains("empty prompt"), "{r:?}");

    // ...and the connection (and engine) stays usable afterwards.
    let r = roundtrip(
        &mut stream,
        &mut reader,
        r#"{"op":"generate","prompt":"zz","max_new_tokens":2}"#,
    );
    assert!(r.get("error").is_none(), "{r:?}");
}

/// A client that disconnects mid-generation must not keep burning engine
/// capacity: the first failed token send cancels the request and frees its
/// KV slot. Driven at the EngineMsg layer (dropping the events receiver IS
/// the disconnect).
#[test]
fn disconnected_client_generation_is_cancelled() {
    let frontend = spawn_engine(AdmissionConfig::default());
    let (ev_tx, ev_rx) = channel();
    drop(ev_rx);
    frontend
        .send(EngineMsg::Generate(GenerateJob {
            id: 9,
            model: None,
            prompt: vec![1, 2, 3],
            max_new_tokens: 50,
            slo: Default::default(),
            events: ev_tx,
        }))
        .unwrap();
    for _ in 0..500 {
        {
            let s = frontend.stats.lock().unwrap();
            // completed counts traces, including the cancellation's failed
            // trace; nothing may remain queued or active.
            if s.completed == 1 && s.active == 0 && s.queued == 0 {
                return;
            }
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    panic!("cancelled generation did not drain");
}

#[test]
fn concurrent_clients_are_served() {
    let (addr, _fe) = start_server(AdmissionConfig::default());
    let handles: Vec<_> = (0..8)
        .map(|i| {
            std::thread::spawn(move || {
                let (mut stream, mut reader) = connect(addr);
                let r = roundtrip(
                    &mut stream,
                    &mut reader,
                    &format!(r#"{{"op":"generate","prompt":"p{i}","max_new_tokens":4}}"#),
                );
                assert!(r.get("error").is_none(), "{r:?}");
                assert_eq!(r.get("tokens").unwrap().as_arr().unwrap().len(), 4);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

/// Backpressure: a gated stub engine holds the first request in flight so
/// the admission outcomes are fully deterministic.
#[test]
fn backpressure_rejects_with_503_and_recovers() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let (frontend, rx) = Frontend::new(AdmissionConfig {
        max_inflight: 2,
        max_inflight_per_adapter: 1,
    });
    // Gate: the stub engine completes one generation per token received on
    // this channel.
    let (gate_tx, gate_rx) = channel::<()>();
    std::thread::spawn(move || {
        while let Ok(msg) = rx.recv() {
            if let EngineMsg::Generate(job) = msg {
                gate_rx.recv().ok();
                let _ = job.events.send(TokenEvent::Token { index: 0, token: 65 });
                let _ = job.events.send(TokenEvent::Done { tokens: vec![65], latency_s: 0.01 });
            }
        }
    });
    let fe = frontend.clone();
    std::thread::spawn(move || {
        let _ = serve_blocking(
            listener,
            fe,
            |text| text.bytes().map(|b| b as i32).collect(),
            |ids| ids.iter().map(|&t| (t as u8) as char).collect(),
        );
    });

    // First request for model "a" occupies its fair share (cap 1).
    let (mut s1, mut r1) = connect(addr);
    send_line(&mut s1, r#"{"op":"generate","prompt":"x","model":"a","max_new_tokens":1}"#);
    // Wait until it is actually admitted (in flight).
    for _ in 0..200 {
        if frontend.inflight() == 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(frontend.inflight(), 1);

    // Same tenant again: fair-share 503.
    let (mut s2, mut r2) = connect(addr);
    let rej = roundtrip(
        &mut s2,
        &mut r2,
        r#"{"op":"generate","prompt":"y","model":"a","max_new_tokens":1}"#,
    );
    assert_eq!(rej.get("code").unwrap().as_usize().unwrap(), 503, "{rej:?}");
    assert!(rej.get("error").unwrap().as_str().unwrap().contains("fair-share"));
    // 503 rejects carry the typed name and a bounded retry hint.
    assert_eq!(rej.get("err").unwrap().as_str().unwrap(), "overloaded", "{rej:?}");
    let hint = rej.get("retry_after_ms").unwrap().as_usize().unwrap();
    assert!((100..=5_000).contains(&hint), "{rej:?}");

    // A different tenant still fits under the global bound...
    send_line(&mut s2, r#"{"op":"generate","prompt":"y","model":"b","max_new_tokens":1}"#);
    for _ in 0..200 {
        if frontend.inflight() == 2 {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(frontend.inflight(), 2);

    // ...and a third tenant trips the global bound.
    let (mut s3, mut r3) = connect(addr);
    let rej = roundtrip(
        &mut s3,
        &mut r3,
        r#"{"op":"generate","prompt":"z","model":"c","max_new_tokens":1}"#,
    );
    assert_eq!(rej.get("code").unwrap().as_usize().unwrap(), 503);
    assert_eq!(rej.get("error").unwrap().as_str().unwrap(), "overloaded");
    assert!(rej.get("retry_after_ms").is_some(), "{rej:?}");

    // Rejections are visible in stats.
    let (mut s4, mut r4) = connect(addr);
    let st = roundtrip(&mut s4, &mut r4, r#"{"op":"stats"}"#);
    assert_eq!(st.get("rejected").unwrap().as_usize().unwrap(), 2);

    // Release both held generations; clients get their replies.
    gate_tx.send(()).unwrap();
    gate_tx.send(()).unwrap();
    let done1 = read_frame(&mut r1);
    assert!(done1.get("error").is_none(), "{done1:?}");
    let done2 = read_frame(&mut r2);
    assert!(done2.get("error").is_none(), "{done2:?}");

    // Capacity freed: the same tenant is admissible again. (Pre-feed the
    // gate so the stub engine replies immediately.)
    for _ in 0..200 {
        if frontend.inflight() == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(frontend.inflight(), 0);
    gate_tx.send(()).unwrap();
    let rr = roundtrip(
        &mut s3,
        &mut r3,
        r#"{"op":"generate","prompt":"w","model":"a","max_new_tokens":1}"#,
    );
    assert!(rr.get("error").is_none(), "{rr:?}");
}

/// Registry mutations are serialized with launches: an unload racing a
/// generation is refused while the adapter has work in flight, and
/// succeeds after it drains. Driven at the EngineMsg layer so ordering is
/// deterministic.
#[test]
fn unload_refused_while_adapter_busy() {
    let frontend = spawn_engine(AdmissionConfig::default());

    // Load an adapter.
    let (tx, rx) = channel();
    frontend
        .send(EngineMsg::Control(ControlMsg {
            op: ControlOp::Load { name: "hot".into(), slot: None, source: AdapterSource::Blank },
            reply: tx,
        }))
        .unwrap();
    assert!(matches!(rx.recv().unwrap(), ControlReply::Loaded { slot: 0, .. }));

    // Enqueue a generation and, back to back, an unload. Both sit in the
    // engine channel before its next message drain, so the unload is
    // handled while the generation is queued/active — and must be refused.
    // (80 tokens ≈ 80 engine steps of margin even if the drain splits.)
    let (ev_tx, ev_rx) = channel();
    frontend
        .send(EngineMsg::Generate(GenerateJob {
            id: 1,
            model: Some("hot".into()),
            prompt: vec![1, 2, 3],
            max_new_tokens: 80,
            slo: Default::default(),
            events: ev_tx,
        }))
        .unwrap();
    let (tx, rx) = channel();
    frontend
        .send(EngineMsg::Control(ControlMsg {
            op: ControlOp::Unload { name: "hot".into() },
            reply: tx,
        }))
        .unwrap();
    match rx.recv().unwrap() {
        ControlReply::Err(e) => assert!(e.contains("busy"), "{e}"),
        other => panic!("unload should be refused while busy, got {other:?}"),
    }

    // The generation still completes correctly...
    let mut tokens = Vec::new();
    loop {
        match ev_rx.recv().unwrap() {
            TokenEvent::Token { token, .. } => tokens.push(token),
            TokenEvent::Done { tokens: full, .. } => {
                assert_eq!(full, tokens);
                assert_eq!(full.len(), 80);
                break;
            }
            TokenEvent::Error { msg, .. } => panic!("unexpected error: {msg}"),
        }
    }

    // ...and once drained, the unload goes through and the slot is reusable.
    let (tx, rx) = channel();
    frontend
        .send(EngineMsg::Control(ControlMsg {
            op: ControlOp::Unload { name: "hot".into() },
            reply: tx,
        }))
        .unwrap();
    assert!(matches!(rx.recv().unwrap(), ControlReply::Unloaded { slot: 0, .. }));
    let (tx, rx) = channel();
    frontend
        .send(EngineMsg::Control(ControlMsg {
            op: ControlOp::Load { name: "next".into(), slot: None, source: AdapterSource::Blank },
            reply: tx,
        }))
        .unwrap();
    assert!(matches!(rx.recv().unwrap(), ControlReply::Loaded { slot: 0, .. }), "slot reused");
}

#[test]
fn graceful_shutdown_drains_then_rejects() {
    let (addr, fe) = start_server(AdmissionConfig::default());

    // A generation in flight when shutdown arrives. (Poll until it has been
    // admitted, so the drain provably covers it; if it already completed,
    // the drain is trivially correct too.)
    let (mut s1, mut r1) = connect(addr);
    send_line(&mut s1, r#"{"op":"generate","prompt":"abcdef","max_new_tokens":20}"#);
    for _ in 0..200 {
        if fe.inflight() >= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }

    let (mut s2, mut r2) = connect(addr);
    let ack = roundtrip(&mut s2, &mut r2, r#"{"op":"shutdown"}"#);
    assert_eq!(ack.get("ok").unwrap().as_bool().unwrap(), true, "{ack:?}");
    assert_eq!(ack.get("drained").unwrap().as_bool().unwrap(), true);

    // The in-flight request was drained, not dropped.
    let done = read_frame(&mut r1);
    assert!(done.get("error").is_none(), "drained request completes: {done:?}");
    assert_eq!(done.get("tokens").unwrap().as_arr().unwrap().len(), 20);

    // New work is refused while/after draining.
    let (mut s3, mut r3) = connect(addr);
    let rej = roundtrip(
        &mut s3,
        &mut r3,
        r#"{"op":"generate","prompt":"x","max_new_tokens":1}"#,
    );
    assert_eq!(rej.get("code").unwrap().as_usize().unwrap(), 503, "{rej:?}");
    assert_eq!(rej.get("error").unwrap().as_str().unwrap(), "draining");
    assert_eq!(rej.get("err").unwrap().as_str().unwrap(), "overloaded");
    assert!(rej.get("retry_after_ms").is_some(), "{rej:?}");
}

/// A half-open client (connected, then silent without FIN) must not pin a
/// connection thread forever: the per-socket read timeout fires and the
/// server closes its side, which the client observes as EOF. A responsive
/// client on the same deployment is unaffected.
#[test]
fn half_open_client_is_reclaimed_by_read_timeout() {
    let (addr, fe) = start_server(AdmissionConfig::default());
    fe.set_conn_timeout_ms(150);

    // Responsive client: normal roundtrip under the (short) timeout.
    let (mut s1, mut r1) = connect(addr);
    let req = r#"{"op":"generate","prompt":"ab","max_new_tokens":2}"#;
    let r = roundtrip(&mut s1, &mut r1, req);
    assert!(r.get("error").is_none(), "{r:?}");

    // Half-open client: sends half a line, then goes silent. The server's
    // read blocks on the missing newline until the timeout reclaims it.
    let (mut s2, mut r2) = connect(addr);
    s2.write_all(b"{\"op\":\"stats\"").unwrap();
    let mut line = String::new();
    let n = r2.read_line(&mut line).unwrap();
    assert_eq!(n, 0, "server closed the half-open connection, got {line:?}");
}
