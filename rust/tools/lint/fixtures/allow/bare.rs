// Fixture: a reasonless escape hatch (linted as module `server`) — it
// suppresses nothing and is itself reported as a lint-allow finding.
pub fn client_latency_s() -> f64 {
    // lint:allow(wall-clock)
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}
