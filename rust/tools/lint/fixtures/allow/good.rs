// Fixture: a justified escape hatch (linted as module `server`). The
// reason is mandatory — it is the reviewer-facing argument for why the
// invariant holds despite the pattern.
pub fn client_latency_s() -> f64 {
    // lint:allow(wall-clock) reports real client-observed latency; never fed back into scheduling
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}
