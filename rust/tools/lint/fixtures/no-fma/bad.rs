// Fixture: a dot product using fused multiply-add (linted as module
// `metrics`; the rule fires repo-wide, even in tests) — FMA rounds once,
// so the result differs in the last bit from separate mul then add,
// breaking the AVX2↔portable bitwise identity (DESIGN.md §11).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).fold(0.0f32, |acc, (x, y)| x.mul_add(*y, acc))
}
