// Fixture: the same dot product with separate IEEE mul then add (linted
// as module `metrics`) — identical bits on every backend.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).fold(0.0f32, |acc, (x, y)| acc + x * y)
}
