// Fixture: supervised request path that can panic on a bad request
// (linted as module `server`) — one malformed frame kills the loop,
// defeating the §12 retry/isolate/quarantine design.
pub fn handle(frame: &str) -> u64 {
    let id: u64 = frame.split(':').next().unwrap().parse().expect("numeric id");
    if id == 0 {
        panic!("id 0 is reserved");
    }
    id
}
