// Fixture: the same handler propagating a typed error (linted as module
// `server`) — a malformed frame becomes an ErrCode reply, and the loop
// keeps serving everyone else.
pub enum ErrCode {
    BadFrame,
    ReservedId,
}

pub fn handle(frame: &str) -> Result<u64, ErrCode> {
    let id: u64 = frame
        .split(':')
        .next()
        .ok_or(ErrCode::BadFrame)?
        .parse()
        .map_err(|_| ErrCode::BadFrame)?;
    if id == 0 {
        return Err(ErrCode::ReservedId);
    }
    Ok(id)
}

#[cfg(test)]
mod tests {
    // Test code is exempt from the panic-free rule: asserting with
    // unwrap/expect here is idiomatic and cannot reach the serving loop.
    #[test]
    fn parses() {
        assert_eq!(super::handle("7:gen").ok().unwrap(), 7);
    }
}
