// Fixture: an unsafe block with no SAFETY argument (linted as module
// `runtime`).
pub fn first(p: *const f32) -> f32 {
    unsafe { p.read() }
}
