// Fixture: the same unsafe sites with their arguments written down
// (linted as module `runtime`).
pub fn first(p: *const f32) -> f32 {
    // SAFETY: caller guarantees `p` points at a live, aligned f32 for
    // the duration of this call (checked at the dispatch site).
    unsafe { p.read() }
}

/// Reads one element past a validated bound.
///
/// # Safety
///
/// `p` must be valid for reads of `i + 1` elements.
pub unsafe fn at(p: *const f32, i: usize) -> f32 {
    unsafe { p.add(i).read() } // SAFETY: `i` in bounds per the fn contract.
}
