// Fixture: engine code spawning its own threads (linted as module
// `engine`) — compute parallelism must go through the partition-only
// worker pool in runtime::parallel (DESIGN.md §7).
pub fn parallel_sum(xs: &'static [f32]) -> f32 {
    let mid = xs.len() / 2;
    let h = std::thread::spawn(move || xs[..mid].iter().sum::<f32>());
    let hi: f32 = xs[mid..].iter().sum();
    hi + h.join().unwrap_or(0.0)
}
