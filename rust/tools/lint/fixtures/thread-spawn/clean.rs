// Fixture: the same reduction through the worker pool (linted as module
// `engine`) — the pool owns every compute thread, so lane count can
// never change output bits.
use crate::runtime::parallel::Pool;

pub fn parallel_sum(pool: &Pool, xs: &[f32]) -> f32 {
    let partials = pool.par_partition(xs, |chunk| chunk.iter().sum::<f32>());
    partials.into_iter().sum()
}
