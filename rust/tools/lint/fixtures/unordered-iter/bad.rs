// Fixture: coordinator stats assembled by iterating a HashMap (linted as
// module `coordinator`) — iteration order is randomized per process and
// leaks straight into the emitted frame.
use std::collections::HashMap;

pub fn stats_frame(per_model: &HashMap<String, usize>) -> String {
    let mut out = String::new();
    for (model, n) in per_model {
        out.push_str(model);
        out.push(':');
        out.push_str(&n.to_string());
        out.push(' ');
    }
    out
}
