// Fixture: the same stats frame over a BTreeMap (linted as module
// `coordinator`) — iteration is key-ordered, so the frame is stable.
use std::collections::BTreeMap;

pub fn stats_frame(per_model: &BTreeMap<String, usize>) -> String {
    let mut out = String::new();
    for (model, n) in per_model {
        out.push_str(model);
        out.push(':');
        out.push_str(&n.to_string());
        out.push(' ');
    }
    out
}
