// Fixture: engine code reading the wall clock directly (linted as module
// `engine`). Scheduling must use the coordinator's virtual clock; real
// durations go through util::bench::Stopwatch.
use std::time::Instant;

pub fn decode_step() -> f64 {
    let t0 = Instant::now();
    // ... work ...
    t0.elapsed().as_secs_f64()
}
