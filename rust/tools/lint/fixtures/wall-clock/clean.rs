// Fixture: engine code measuring through the audited choke point (linted
// as module `engine`).
use crate::util::bench::Stopwatch;

pub fn decode_step() -> f64 {
    let t0 = Stopwatch::start();
    // ... work ...
    t0.elapsed_s()
}
