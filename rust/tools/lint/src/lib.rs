//! `loquetier-lint`: a std-only invariant linter for the Loquetier tree.
//!
//! Every headline claim in this reproduction is a *contract*: the SMLM
//! unified launch is bitwise output-transparent, the worker pool is
//! partition-only thread-invariant (DESIGN.md §7), the AVX2 kernels are
//! bitwise-identical to the portable fallback because they use mul/add
//! only (§11), and the supervised engine loop survives any single bad
//! request (§12). Those contracts are conventions in source code — one
//! careless `HashMap` iteration, stray `Instant::now`, or hot-path
//! `unwrap()` silently breaks them. This tool makes them machine-checked
//! on every PR (DESIGN.md §13).
//!
//! Design constraints: the offline build image has no crates.io, so there
//! is no `syn` — the linter lexes `.rs` files itself with a
//! comment/string-aware tokenizer, scopes rules by module path (derived
//! from the file's location under the source root) and by `#[cfg(test)]`
//! spans (brace-matched), and applies the six named rules below. Findings
//! print rustc-style as `file:line: lint[rule-id]: message` and the
//! process exits nonzero when any remain.
//!
//! Escape hatch: `// lint:allow(rule-id) reason` on the offending line
//! (trailing) or on a comment line directly above it suppresses one
//! rule there — but only with a non-empty written reason; a bare
//! `lint:allow` is itself a finding. Honored escapes are counted in the
//! summary so reviewers can watch the total.

use std::fmt;
use std::fs;
use std::path::Path;

// ---------------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------------

/// The project invariants the linter enforces. `LintAllow` is the
/// meta-rule for malformed escape hatches and cannot itself be allowed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// `Instant::now` / `SystemTime` outside `util::bench` and `main`:
    /// engine and scheduler code runs on the coordinator's virtual clock;
    /// real time may only enter through the audited `util::bench`
    /// stopwatch (measurement that is *reported*, never *scheduled on*).
    WallClock,
    /// `HashMap`/`HashSet` in `coordinator`/`engine`/`runtime`/`server`:
    /// their iteration order is randomized per process, so any order that
    /// reaches a launch, a frame, or a trajectory file breaks bitwise
    /// reproducibility. Use `BTreeMap`/`BTreeSet` or sort the keys.
    UnorderedIter,
    /// `std::thread` spawning outside `runtime::parallel`: all compute
    /// parallelism must go through the partition-only worker pool
    /// (DESIGN.md §7) so thread count can never change a bit of output.
    ThreadSpawn,
    /// An `unsafe` block, fn, or impl without an immediately preceding
    /// `// SAFETY:` comment (or `# Safety` doc section) stating the
    /// aliasing/bounds/feature-detection argument.
    SafetyComment,
    /// `mul_add` / fused-multiply-add intrinsics anywhere: the AVX2 and
    /// portable kernels are bitwise interchangeable only because both do
    /// separate IEEE mul then add (DESIGN.md §11). FMA rounds once.
    NoFma,
    /// `unwrap`/`expect`/`panic!`/`unreachable!`/`panic_any` in non-test
    /// `coordinator`/`server`/`engine` code: a stray panic in the
    /// supervised request path defeats the §12 blast-radius design
    /// (retry → isolate → quarantine; one bad request never kills the
    /// loop). Propagate errors or emit typed `ErrCode` frames instead.
    PanicFreeSupervised,
    /// A `lint:allow` escape that is malformed: empty reason or unknown
    /// rule id. Escapes must carry a written justification.
    LintAllow,
}

impl Rule {
    pub fn id(self) -> &'static str {
        match self {
            Rule::WallClock => "wall-clock",
            Rule::UnorderedIter => "unordered-iter",
            Rule::ThreadSpawn => "thread-spawn",
            Rule::SafetyComment => "safety-comment",
            Rule::NoFma => "no-fma",
            Rule::PanicFreeSupervised => "panic-free-supervised",
            Rule::LintAllow => "lint-allow",
        }
    }

    /// Rule ids clients may name in `lint:allow(..)` (everything except
    /// the meta-rule).
    pub fn from_id(id: &str) -> Option<Rule> {
        match id {
            "wall-clock" => Some(Rule::WallClock),
            "unordered-iter" => Some(Rule::UnorderedIter),
            "thread-spawn" => Some(Rule::ThreadSpawn),
            "safety-comment" => Some(Rule::SafetyComment),
            "no-fma" => Some(Rule::NoFma),
            "panic-free-supervised" => Some(Rule::PanicFreeSupervised),
            _ => None,
        }
    }
}

/// One reported violation.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: lint[{}]: {}", self.file, self.line, self.rule.id(), self.msg)
    }
}

/// Lint result for one file.
#[derive(Debug, Default)]
pub struct FileResult {
    pub findings: Vec<Finding>,
    /// `lint:allow` escapes present in the file.
    pub allows_total: usize,
    /// Escapes that suppressed at least one finding.
    pub allows_honored: usize,
}

/// Aggregate over a tree walk.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub files: usize,
    pub allows_total: usize,
    pub allows_honored: usize,
}

impl Report {
    pub fn absorb(&mut self, r: FileResult) {
        self.findings.extend(r.findings);
        self.files += 1;
        self.allows_total += r.allows_total;
        self.allows_honored += r.allows_honored;
    }
}

// ---------------------------------------------------------------------------
// Lexer
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Ident(String),
    Punct(char),
    /// String/char/numeric literal (content irrelevant to every rule).
    Lit,
}

#[derive(Debug)]
struct TokAt {
    tok: Tok,
    line: usize,
    in_test: bool,
}

/// What a source line consists of, for the SAFETY-comment climb.
#[derive(Debug, Clone, Copy, PartialEq)]
enum LineKind {
    Blank,
    CommentOnly,
    /// First code token is `#` — an attribute line (climbed over).
    Attr,
    Code,
}

struct Lexed {
    toks: Vec<TokAt>,
    /// 1-based; index 0 unused.
    line_kind: Vec<LineKind>,
    /// 1-based; concatenated comment text per line.
    comment_text: Vec<String>,
    lines: usize,
}

fn lex(src: &str) -> Lexed {
    let bytes: Vec<char> = src.chars().collect();
    let n = bytes.len();
    let total_lines = src.lines().count().max(1);
    let mut toks: Vec<TokAt> = Vec::new();
    let mut line_has_code = vec![false; total_lines + 2];
    let mut line_first_hash = vec![false; total_lines + 2];
    let mut line_has_comment = vec![false; total_lines + 2];
    let mut comment_text = vec![String::new(); total_lines + 2];

    let mut i = 0usize;
    let mut line = 1usize;
    let mut push = |tok: Tok, line: usize, toks: &mut Vec<TokAt>| {
        if !line_has_code[line] {
            line_first_hash[line] = tok == Tok::Punct('#');
        }
        line_has_code[line] = true;
        toks.push(TokAt { tok, line, in_test: false });
    };

    while i < n {
        let c = bytes[i];
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_whitespace() => i += 1,
            // Line comment (incl. doc comments).
            '/' if i + 1 < n && bytes[i + 1] == '/' => {
                let start = i;
                while i < n && bytes[i] != '\n' {
                    i += 1;
                }
                let text: String = bytes[start..i].iter().collect();
                line_has_comment[line] = true;
                comment_text[line].push_str(&text);
                comment_text[line].push(' ');
            }
            // Block comment (nesting, per Rust).
            '/' if i + 1 < n && bytes[i + 1] == '*' => {
                let mut depth = 1;
                let start_line = line;
                let mut text = String::new();
                i += 2;
                while i < n && depth > 0 {
                    if bytes[i] == '/' && i + 1 < n && bytes[i + 1] == '*' {
                        depth += 1;
                        i += 2;
                    } else if bytes[i] == '*' && i + 1 < n && bytes[i + 1] == '/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        if bytes[i] == '\n' {
                            line_has_comment[line] = true;
                            comment_text[line].push_str(&text);
                            comment_text[line].push(' ');
                            text.clear();
                            line += 1;
                        } else {
                            text.push(bytes[i]);
                        }
                        i += 1;
                    }
                }
                line_has_comment[line.min(total_lines)] = true;
                comment_text[line.min(total_lines)].push_str(&text);
                comment_text[line.min(total_lines)].push(' ');
                let _ = start_line;
            }
            // String literals: plain, raw (any # count), byte, raw-byte.
            '"' => {
                i = skip_string(&bytes, i, &mut line);
                push(Tok::Lit, line, &mut toks);
            }
            'r' | 'b' if starts_string(&bytes, i) => {
                // Advance past the r/b/rb/br prefix to any `#`s and the `"`.
                let mut raw = c == 'r';
                let mut j = i + 1;
                if j < n && (bytes[j] == 'b' || bytes[j] == 'r') {
                    raw = raw || bytes[j] == 'r';
                    j += 1;
                }
                let mut hashes = 0;
                while j < n && bytes[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if !raw {
                    // b"..." — escapes are processed like a normal string.
                    i = skip_string(&bytes, j, &mut line);
                } else {
                    // Raw string: no escapes; closes at `"` + matching `#`s.
                    j += 1; // opening quote
                    loop {
                        if j >= n {
                            break;
                        }
                        if bytes[j] == '\n' {
                            line += 1;
                            j += 1;
                            continue;
                        }
                        if bytes[j] == '"' {
                            let mut k = j + 1;
                            let mut seen = 0;
                            while k < n && bytes[k] == '#' && seen < hashes {
                                seen += 1;
                                k += 1;
                            }
                            if seen == hashes {
                                j = k;
                                break;
                            }
                        }
                        j += 1;
                    }
                    i = j;
                }
                push(Tok::Lit, line, &mut toks);
            }
            // Char literal vs lifetime.
            '\'' => {
                let next = bytes.get(i + 1).copied().unwrap_or(' ');
                let after = bytes.get(i + 2).copied().unwrap_or(' ');
                if next == '\\' {
                    // Escaped char literal: skip to closing quote.
                    let mut j = i + 2;
                    if j < n {
                        j += 1; // the escaped char (or first of \x..)
                    }
                    while j < n && bytes[j] != '\'' {
                        j += 1;
                    }
                    i = j + 1;
                    push(Tok::Lit, line, &mut toks);
                } else if after == '\'' && next != '\'' {
                    // 'c'
                    i += 3;
                    push(Tok::Lit, line, &mut toks);
                } else {
                    // Lifetime: consume the tick + identifier.
                    i += 1;
                    while i < n && (bytes[i].is_alphanumeric() || bytes[i] == '_') {
                        i += 1;
                    }
                    push(Tok::Lit, line, &mut toks);
                }
            }
            c if c.is_alphabetic() || c == '_' => {
                let start = i;
                while i < n && (bytes[i].is_alphanumeric() || bytes[i] == '_') {
                    i += 1;
                }
                let ident: String = bytes[start..i].iter().collect();
                push(Tok::Ident(ident), line, &mut toks);
            }
            c if c.is_ascii_digit() => {
                // Numeric literal; `.` only consumed when not a `..` range.
                while i < n
                    && (bytes[i].is_alphanumeric()
                        || bytes[i] == '_'
                        || (bytes[i] == '.'
                            && bytes.get(i + 1).is_some_and(|d| d.is_ascii_digit())))
                {
                    i += 1;
                }
                push(Tok::Lit, line, &mut toks);
            }
            c => {
                push(Tok::Punct(c), line, &mut toks);
                i += 1;
            }
        }
    }

    mark_test_spans(&mut toks);

    let mut line_kind = vec![LineKind::Blank; total_lines + 2];
    for (l, kind) in line_kind.iter_mut().enumerate().take(total_lines + 1).skip(1) {
        *kind = if line_has_code[l] {
            if line_first_hash[l] {
                LineKind::Attr
            } else {
                LineKind::Code
            }
        } else if line_has_comment[l] {
            LineKind::CommentOnly
        } else {
            LineKind::Blank
        };
    }

    Lexed { toks, line_kind, comment_text, lines: total_lines }
}

fn starts_string(bytes: &[char], i: usize) -> bool {
    // r" r#" rb" b" br" b' are literal prefixes; `r`/`b` followed by
    // anything else is an identifier start.
    let mut j = i + 1;
    if j < bytes.len() && (bytes[j] == 'b' || bytes[j] == 'r') {
        j += 1;
    }
    while j < bytes.len() && bytes[j] == '#' {
        j += 1;
    }
    j < bytes.len() && bytes[j] == '"'
}

/// Skip a `"`-delimited string starting at `i` (pointing at the opening
/// quote); returns the index after the closing quote, tracking newlines.
fn skip_string(bytes: &[char], i: usize, line: &mut usize) -> usize {
    let n = bytes.len();
    let mut j = i + 1;
    while j < n {
        match bytes[j] {
            '\\' => j += 2,
            '"' => return j + 1,
            '\n' => {
                *line += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    j
}

/// Mark tokens inside `#[cfg(test)]`/`#[test]` items as test-scoped. The
/// gated item extends to its matching close brace, or to the first
/// top-level `;` for brace-less items (`use`, statics).
fn mark_test_spans(toks: &mut [TokAt]) {
    let is = |t: &TokAt, c: char| t.tok == Tok::Punct(c);
    let ident = |t: &TokAt, s: &str| matches!(&t.tok, Tok::Ident(id) if id == s);
    let mut i = 0;
    while i < toks.len() {
        if toks[i].in_test {
            i += 1;
            continue;
        }
        // `# [ cfg ( test ) ]` or `# [ test ]`
        let attr_end = if i + 6 < toks.len()
            && is(&toks[i], '#')
            && is(&toks[i + 1], '[')
            && ident(&toks[i + 2], "cfg")
            && is(&toks[i + 3], '(')
            && ident(&toks[i + 4], "test")
            && is(&toks[i + 5], ')')
            && is(&toks[i + 6], ']')
        {
            Some(i + 7)
        } else if i + 3 < toks.len()
            && is(&toks[i], '#')
            && is(&toks[i + 1], '[')
            && ident(&toks[i + 2], "test")
            && is(&toks[i + 3], ']')
        {
            Some(i + 4)
        } else {
            None
        };
        let Some(start) = attr_end else {
            i += 1;
            continue;
        };
        // Walk to the end of the gated item.
        let mut j = start;
        let mut depth = 0usize;
        let mut end = toks.len();
        while j < toks.len() {
            if is(&toks[j], '{') {
                depth += 1;
            } else if is(&toks[j], '}') {
                depth -= 1;
                if depth == 0 {
                    end = j + 1;
                    break;
                }
            } else if is(&toks[j], ';') && depth == 0 {
                end = j + 1;
                break;
            }
            j += 1;
        }
        for t in toks.iter_mut().take(end).skip(i) {
            t.in_test = true;
        }
        i = end;
    }
}

// ---------------------------------------------------------------------------
// Allow escapes
// ---------------------------------------------------------------------------

#[derive(Debug)]
struct Allow {
    line: usize,
    /// The line this escape covers (own line when trailing, the next
    /// code/attr line when on a comment-only line).
    target: Option<usize>,
    rule: Option<Rule>,
    raw_rule: String,
    reason: String,
    honored: bool,
}

fn parse_allows(lx: &Lexed) -> Vec<Allow> {
    let mut allows = Vec::new();
    for l in 1..=lx.lines {
        let text = &lx.comment_text[l];
        let mut rest = text.as_str();
        while let Some(pos) = rest.find("lint:allow(") {
            let after = &rest[pos + "lint:allow(".len()..];
            let Some(close) = after.find(')') else { break };
            let raw_rule = after[..close].trim().to_string();
            let reason = after[close + 1..]
                .split("lint:allow(")
                .next()
                .unwrap_or("")
                .trim()
                .to_string();
            let target = if lx.line_kind[l] == LineKind::CommentOnly {
                // Covers the next code line, climbing over further comment
                // and attribute lines; a blank line breaks the tie.
                let mut t = l + 1;
                loop {
                    if t > lx.lines {
                        break None;
                    }
                    match lx.line_kind[t] {
                        LineKind::Code => break Some(t),
                        LineKind::CommentOnly | LineKind::Attr => t += 1,
                        LineKind::Blank => break None,
                    }
                }
            } else {
                Some(l)
            };
            allows.push(Allow {
                line: l,
                target,
                rule: Rule::from_id(&raw_rule),
                raw_rule,
                reason,
                honored: false,
            });
            rest = &after[close + 1..];
        }
    }
    allows
}

// ---------------------------------------------------------------------------
// Rule engine
// ---------------------------------------------------------------------------

/// Modules whose iteration order can reach a launch, a frame, or a
/// trajectory file. `kvcache` joined with the §14 radix prefix index: its
/// probe/evict order decides which blocks admissions attach to, so an
/// unordered map there would make whole schedules nondeterministic.
const ORDERED_MODULES: &[&str] = &["coordinator", "engine", "kvcache", "runtime", "server"];
/// Modules on the supervised request path (DESIGN.md §12).
const SUPERVISED_MODULES: &[&str] = &["coordinator", "server", "engine"];

fn top_module(module: &str) -> &str {
    module.split("::").next().unwrap_or(module)
}

/// Lint one file's source. `module` is its module path relative to the
/// crate root (`coordinator`, `util::bench`, `main`, ...); fixture tests
/// pass it explicitly, the tree walker derives it from the path.
pub fn lint_source(path_label: &str, module: &str, src: &str) -> FileResult {
    let lx = lex(src);
    let mut allows = parse_allows(&lx);
    let raw = raw_findings(module, &lx);

    let mut findings: Vec<Finding> = Vec::new();
    for (line, rule, msg) in raw {
        let suppressed = allows.iter_mut().any(|a| {
            let ok = a.target == Some(line) && a.rule == Some(rule) && !a.reason.is_empty();
            if ok {
                a.honored = true;
            }
            ok
        });
        if !suppressed {
            findings.push(Finding { file: path_label.to_string(), line, rule, msg });
        }
    }
    // Malformed escapes are findings in their own right.
    for a in &allows {
        if a.rule.is_none() {
            findings.push(Finding {
                file: path_label.to_string(),
                line: a.line,
                rule: Rule::LintAllow,
                msg: format!(
                    "lint:allow names unknown rule '{}' (known: wall-clock, unordered-iter, \
                     thread-spawn, safety-comment, no-fma, panic-free-supervised)",
                    a.raw_rule
                ),
            });
        } else if a.reason.is_empty() {
            findings.push(Finding {
                file: path_label.to_string(),
                line: a.line,
                rule: Rule::LintAllow,
                msg: format!(
                    "lint:allow({}) without a reason — write why the invariant holds here",
                    a.raw_rule
                ),
            });
        }
    }
    findings.sort_by_key(|f| f.line);
    FileResult {
        allows_total: allows.len(),
        allows_honored: allows.iter().filter(|a| a.honored).count(),
        findings,
    }
}

fn raw_findings(module: &str, lx: &Lexed) -> Vec<(usize, Rule, String)> {
    let mut out: Vec<(usize, Rule, String)> = Vec::new();
    let top = top_module(module);
    let toks = &lx.toks;
    let ident_at = |i: usize| match toks.get(i).map(|t| &t.tok) {
        Some(Tok::Ident(s)) => Some(s.as_str()),
        _ => None,
    };
    let punct_at = |i: usize, c: char| toks.get(i).map(|t| t.tok == Tok::Punct(c)) == Some(true);
    let path_sep = |i: usize| punct_at(i, ':') && punct_at(i + 1, ':');

    let mut unsafe_lines_seen: Vec<usize> = Vec::new();

    for i in 0..toks.len() {
        let t = &toks[i];
        let Tok::Ident(id) = &t.tok else { continue };
        let line = t.line;
        let in_test = t.in_test;

        // -- wall-clock ----------------------------------------------------
        if !in_test && module != "util::bench" && module != "main" {
            let instant_now =
                id == "Instant" && path_sep(i + 1) && ident_at(i + 3) == Some("now");
            if instant_now || id == "SystemTime" {
                out.push((
                    line,
                    Rule::WallClock,
                    format!(
                        "{} reads the wall clock outside util::bench/main — engine and \
                         scheduler code runs on the virtual clock; measure real durations \
                         through util::bench::Stopwatch (the audited choke point)",
                        if instant_now { "Instant::now" } else { "SystemTime" }
                    ),
                ));
            }
        }

        // -- unordered-iter ------------------------------------------------
        if !in_test
            && ORDERED_MODULES.contains(&top)
            && (id == "HashMap" || id == "HashSet")
        {
            out.push((
                line,
                Rule::UnorderedIter,
                format!(
                    "{id} in `{top}` — iteration order is randomized per process and can \
                     leak into launches, frames or trajectories; use BTreeMap/BTreeSet or \
                     sorted keys (or justify with lint:allow(unordered-iter) why the order \
                     provably cannot reach output)"
                ),
            ));
        }

        // -- thread-spawn --------------------------------------------------
        if !in_test
            && module != "runtime::parallel"
            && id == "thread"
            && path_sep(i + 1)
            && matches!(ident_at(i + 3), Some("spawn") | Some("Builder"))
        {
            out.push((
                line,
                Rule::ThreadSpawn,
                "thread spawn outside runtime::parallel — compute parallelism must use \
                 the partition-only worker pool (DESIGN.md §7) so lane count never \
                 changes output bits"
                    .to_string(),
            ));
        }

        // -- safety-comment ------------------------------------------------
        if id == "unsafe" && !unsafe_lines_seen.contains(&line) {
            unsafe_lines_seen.push(line);
            if !has_safety_comment(lx, line) {
                out.push((
                    line,
                    Rule::SafetyComment,
                    "`unsafe` without an immediately preceding `// SAFETY:` comment — \
                     state the aliasing/bounds/feature-detection argument for this site"
                        .to_string(),
                ));
            }
        }

        // -- no-fma --------------------------------------------------------
        if id == "mul_add" || id.contains("fmadd") {
            out.push((
                line,
                Rule::NoFma,
                format!(
                    "{id} fuses multiply-add with a single rounding — the AVX2 and \
                     portable kernels are bitwise interchangeable only under separate \
                     IEEE mul/add (DESIGN.md §11)"
                ),
            ));
        }

        // -- panic-free-supervised -----------------------------------------
        if !in_test && SUPERVISED_MODULES.contains(&top) {
            let method_call = |name: &str| {
                id == name && i > 0 && toks[i - 1].tok == Tok::Punct('.') && punct_at(i + 1, '(')
            };
            let bang_macro = |name: &str| id == name && punct_at(i + 1, '!');
            let what = if method_call("unwrap") {
                Some(".unwrap()")
            } else if method_call("expect") {
                Some(".expect()")
            } else if bang_macro("panic") {
                Some("panic!")
            } else if bang_macro("unreachable") {
                Some("unreachable!")
            } else if bang_macro("todo") {
                Some("todo!")
            } else if bang_macro("unimplemented") {
                Some("unimplemented!")
            } else if id == "panic_any" {
                Some("panic_any")
            } else {
                None
            };
            if let Some(what) = what {
                out.push((
                    line,
                    Rule::PanicFreeSupervised,
                    format!(
                        "{what} on the supervised request path (`{top}`) — a stray panic \
                         defeats the §12 retry/isolate/quarantine blast-radius design; \
                         propagate an error or emit a typed ErrCode frame"
                    ),
                ));
            }
        }
    }
    out
}

/// The SAFETY contract must be on the same line (trailing comment) or in
/// the contiguous comment block immediately above the `unsafe` line
/// (attribute lines like `#[target_feature(...)]` are climbed over).
fn has_safety_comment(lx: &Lexed, line: usize) -> bool {
    let marked = |l: usize| {
        let t = &lx.comment_text[l];
        t.contains("SAFETY") || t.contains("# Safety")
    };
    if marked(line) {
        return true;
    }
    let mut j = line.saturating_sub(1);
    while j >= 1 {
        match lx.line_kind[j] {
            LineKind::CommentOnly => {
                if marked(j) {
                    return true;
                }
                j -= 1;
            }
            LineKind::Attr => j -= 1,
            LineKind::Blank | LineKind::Code => return false,
        }
    }
    false
}

// ---------------------------------------------------------------------------
// Tree walking
// ---------------------------------------------------------------------------

/// Derive the module path of `file` relative to the source root it was
/// found under: `coordinator/mod.rs` → `coordinator`,
/// `util/bench.rs` → `util::bench`, `main.rs` → `main`.
fn module_of(rel: &Path) -> String {
    let mut parts: Vec<String> = rel
        .components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect();
    if let Some(last) = parts.pop() {
        let stem = last.trim_end_matches(".rs");
        if stem != "mod" {
            parts.push(stem.to_string());
        }
    }
    if parts.is_empty() {
        "crate".to_string()
    } else {
        parts.join("::")
    }
}

/// Lint every `.rs` file under `root` (a file or directory). Directory
/// entries are visited in sorted order so output is deterministic — the
/// linter holds itself to the invariants it enforces.
pub fn lint_path(root: &Path, report: &mut Report) -> Result<(), String> {
    if root.is_file() {
        return lint_file(root, root.parent().unwrap_or(Path::new("")), report);
    }
    if !root.is_dir() {
        return Err(format!("{}: not a file or directory", root.display()));
    }
    walk(root, root, report)
}

fn walk(dir: &Path, root: &Path, report: &mut Report) -> Result<(), String> {
    let rd = fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let mut entries: Vec<_> = rd
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            if p.file_name().is_some_and(|n| n == "target") {
                continue;
            }
            walk(&p, root, report)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            lint_file(&p, root, report)?;
        }
    }
    Ok(())
}

fn lint_file(path: &Path, root: &Path, report: &mut Report) -> Result<(), String> {
    let src = fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let rel = path.strip_prefix(root).unwrap_or(path);
    let module = module_of(rel);
    report.absorb(lint_source(&path.display().to_string(), &module, &src));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(r: &FileResult) -> Vec<Rule> {
        r.findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn tokenizer_ignores_strings_and_comments() {
        let src = r#"
            fn f() {
                let s = "Instant::now() HashMap unsafe mul_add";
                let c = 'u'; // Instant::now in a comment
                /* HashMap::new() in a block comment */
                let r = r"unsafe panic!";
            }
        "#;
        let r = lint_source("t.rs", "coordinator", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn cfg_test_mod_is_exempt_from_scoped_rules() {
        let src = "
            #[cfg(test)]
            mod tests {
                use std::collections::HashMap;
                fn f() {
                    let m: HashMap<u32, u32> = HashMap::new();
                    m.get(&1).unwrap();
                }
            }
        ";
        let r = lint_source("t.rs", "coordinator", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn cfg_test_use_item_does_not_swallow_following_code() {
        let src = "
            #[cfg(test)]
            use std::collections::HashMap;
            fn f(m: &std::collections::HashMap<u32, u32>) {
                m.get(&1).unwrap();
            }
        ";
        let r = lint_source("t.rs", "coordinator", src);
        // The brace-less gated item ends at its `;`: the fn below is NOT
        // test code, so both the HashMap mention and the unwrap fire.
        assert_eq!(
            rules_of(&r),
            vec![Rule::UnorderedIter, Rule::PanicFreeSupervised],
            "{:?}",
            r.findings
        );
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "
            fn f<'a>(x: &'a str) -> &'a str {
                let m: std::collections::HashMap<&'a str, u32>;
                x
            }
        ";
        let r = lint_source("t.rs", "server", src);
        assert_eq!(rules_of(&r), vec![Rule::UnorderedIter]);
    }

    #[test]
    fn module_scoping_controls_rules() {
        let src = "fn f() { let t = Instant::now(); }";
        assert!(lint_source("t.rs", "util::bench", src).findings.is_empty());
        assert!(lint_source("t.rs", "main", src).findings.is_empty());
        assert_eq!(rules_of(&lint_source("t.rs", "metrics", src)), vec![Rule::WallClock]);

        let spawn = "fn f() { std::thread::spawn(|| {}); }";
        assert!(lint_source("t.rs", "runtime::parallel", spawn).findings.is_empty());
        assert_eq!(
            rules_of(&lint_source("t.rs", "server", spawn)),
            vec![Rule::ThreadSpawn]
        );

        let map = "fn f() { let m: HashMap<u32, u32>; }";
        assert!(lint_source("t.rs", "metrics", map).findings.is_empty());
        assert_eq!(
            rules_of(&lint_source("t.rs", "runtime", map)),
            vec![Rule::UnorderedIter]
        );
        // The §14 radix prefix index made kvcache order-bearing: probe and
        // evict order reach the schedule, so hash maps are banned there too.
        assert_eq!(
            rules_of(&lint_source("t.rs", "kvcache", map)),
            vec![Rule::UnorderedIter]
        );
    }

    #[test]
    fn unwrap_or_is_not_unwrap() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap_or(0) }";
        assert!(lint_source("t.rs", "coordinator", src).findings.is_empty());
    }

    #[test]
    fn safety_comment_accepts_trailing_block_and_doc_forms() {
        let trailing = "fn f(p: *const u8) { unsafe { p.read() }; } // SAFETY: p valid";
        assert!(lint_source("t.rs", "runtime", trailing).findings.is_empty());

        let above = "
            fn f(p: *const u8) {
                // SAFETY: caller guarantees p is valid for reads.
                unsafe { p.read() };
            }
        ";
        assert!(lint_source("t.rs", "runtime", above).findings.is_empty());

        let doc = "
            /// Does a thing.
            ///
            /// # Safety
            ///
            /// `p` must be valid.
            #[inline]
            pub unsafe fn f(p: *const u8) -> u8 { p.read() }
        ";
        assert!(lint_source("t.rs", "runtime", doc).findings.is_empty());

        let missing = "
            fn f(p: *const u8) {
                let x = 1;
                unsafe { p.read() };
            }
        ";
        assert_eq!(
            rules_of(&lint_source("t.rs", "runtime", missing)),
            vec![Rule::SafetyComment]
        );
    }

    #[test]
    fn safety_comment_does_not_leak_across_code_lines() {
        let src = "
            fn f(p: *const u8) {
                // SAFETY: p valid for the first read.
                unsafe { p.read() };
                unsafe { p.add(1).read() };
            }
        ";
        let r = lint_source("t.rs", "runtime", src);
        assert_eq!(rules_of(&r), vec![Rule::SafetyComment]);
        assert_eq!(r.findings[0].line, 5);
    }

    #[test]
    fn allow_with_reason_suppresses_and_is_counted() {
        let src = "
            fn f() {
                // lint:allow(wall-clock) frontend reports real client latency
                let t = Instant::now();
            }
        ";
        let r = lint_source("t.rs", "server", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        assert_eq!((r.allows_total, r.allows_honored), (1, 1));

        let trailing = "fn f() { let t = Instant::now(); } // lint:allow(wall-clock) measured, reported";
        let r = lint_source("t.rs", "server", trailing);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn allow_without_reason_is_itself_a_finding() {
        let src = "
            fn f() {
                // lint:allow(wall-clock)
                let t = Instant::now();
            }
        ";
        let r = lint_source("t.rs", "server", src);
        // The bare escape suppresses nothing AND reports itself.
        assert!(rules_of(&r).contains(&Rule::WallClock));
        assert!(rules_of(&r).contains(&Rule::LintAllow));
    }

    #[test]
    fn allow_unknown_rule_is_a_finding() {
        let src = "fn f() {} // lint:allow(no-such-rule) because reasons";
        let r = lint_source("t.rs", "server", src);
        assert_eq!(rules_of(&r), vec![Rule::LintAllow]);
    }

    #[test]
    fn allow_does_not_cross_a_blank_line() {
        let src = "
            fn f() {
                // lint:allow(wall-clock) stale escape, separated by a blank

                let t = Instant::now();
            }
        ";
        let r = lint_source("t.rs", "server", src);
        assert!(rules_of(&r).contains(&Rule::WallClock));
        assert_eq!(r.allows_honored, 0);
    }

    #[test]
    fn module_of_paths() {
        assert_eq!(module_of(Path::new("coordinator/mod.rs")), "coordinator");
        assert_eq!(module_of(Path::new("util/bench.rs")), "util::bench");
        assert_eq!(module_of(Path::new("main.rs")), "main");
        assert_eq!(module_of(Path::new("engine/native.rs")), "engine::native");
    }

    #[test]
    fn no_fma_fires_everywhere_even_in_tests() {
        let src = "
            #[cfg(test)]
            mod tests {
                fn f(a: f32) -> f32 { a.mul_add(2.0, 1.0) }
            }
        ";
        let r = lint_source("t.rs", "metrics", src);
        assert_eq!(rules_of(&r), vec![Rule::NoFma]);
    }
}
