//! CLI for `loquetier-lint`. Usage: `loquetier-lint <dir-or-file>...`
//!
//! Prints findings as `file:line: lint[rule-id]: message`, then a summary
//! line `loquetier-lint: files=N findings=N allows=N honored=N` that CI
//! greps into its job-summary table. Exit codes: 0 clean, 1 findings,
//! 2 usage or I/O error.

use std::path::Path;
use std::process::ExitCode;

use loquetier_lint::{lint_path, Report};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprintln!("usage: loquetier-lint <dir-or-file>...");
        eprintln!("  lints .rs files against the DESIGN.md \u{00a7}13 invariants");
        return ExitCode::from(2);
    }

    let mut report = Report::default();
    for arg in &args {
        if let Err(e) = lint_path(Path::new(arg), &mut report) {
            eprintln!("loquetier-lint: {e}");
            return ExitCode::from(2);
        }
    }

    for f in &report.findings {
        println!("{f}");
    }
    println!(
        "loquetier-lint: files={} findings={} allows={} honored={}",
        report.files,
        report.findings.len(),
        report.allows_total,
        report.allows_honored
    );
    if report.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
