//! Fixture-corpus tests: every rule fires on its bad fixture and stays
//! silent on the clean one, the escape-hatch semantics hold, and — the
//! gate this crate exists for — the real tree under `rust/src` is clean.

use std::path::{Path, PathBuf};

use loquetier_lint::{lint_path, lint_source, FileResult, Report, Rule};

fn fixture(rule_dir: &str, name: &str) -> String {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("fixtures")
        .join(rule_dir)
        .join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("{}: {e}", p.display()))
}

fn lint_fixture(rule_dir: &str, name: &str, module: &str) -> FileResult {
    lint_source(&format!("{rule_dir}/{name}"), module, &fixture(rule_dir, name))
}

/// (fixture dir, rule, module the fixture is linted as)
const CASES: &[(&str, Rule, &str)] = &[
    ("wall-clock", Rule::WallClock, "engine"),
    ("unordered-iter", Rule::UnorderedIter, "coordinator"),
    ("thread-spawn", Rule::ThreadSpawn, "engine"),
    ("safety-comment", Rule::SafetyComment, "runtime"),
    ("no-fma", Rule::NoFma, "metrics"),
    ("panic-free-supervised", Rule::PanicFreeSupervised, "server"),
];

#[test]
fn every_rule_fires_on_its_bad_fixture() {
    for &(dir, rule, module) in CASES {
        let r = lint_fixture(dir, "bad.rs", module);
        assert!(
            r.findings.iter().any(|f| f.rule == rule),
            "{dir}/bad.rs: expected a {} finding, got {:?}",
            rule.id(),
            r.findings
        );
        // A true positive, not collateral: every finding is the rule
        // under test.
        assert!(
            r.findings.iter().all(|f| f.rule == rule),
            "{dir}/bad.rs: unexpected extra findings {:?}",
            r.findings
        );
    }
}

#[test]
fn every_rule_is_silent_on_its_clean_fixture() {
    for &(dir, _, module) in CASES {
        let r = lint_fixture(dir, "clean.rs", module);
        assert!(
            r.findings.is_empty(),
            "{dir}/clean.rs: expected clean, got {:?}",
            r.findings
        );
    }
}

#[test]
fn allow_with_reason_suppresses() {
    let r = lint_fixture("allow", "good.rs", "server");
    assert!(r.findings.is_empty(), "allow/good.rs: {:?}", r.findings);
    assert_eq!((r.allows_total, r.allows_honored), (1, 1));
}

#[test]
fn allow_without_reason_is_a_finding_and_suppresses_nothing() {
    let r = lint_fixture("allow", "bare.rs", "server");
    assert!(
        r.findings.iter().any(|f| f.rule == Rule::LintAllow),
        "allow/bare.rs: expected a lint-allow finding, got {:?}",
        r.findings
    );
    assert!(
        r.findings.iter().any(|f| f.rule == Rule::WallClock),
        "allow/bare.rs: the bare escape must not suppress the wall-clock \
         finding, got {:?}",
        r.findings
    );
    assert_eq!(r.allows_honored, 0);
}

/// The tree gate: `rust/src` must lint clean with every escape hatch
/// justified. This is the same invocation CI runs; a red test here means
/// a contract from DESIGN.md §13 regressed.
#[test]
fn repo_tree_is_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../src");
    let src = src.canonicalize().expect("rust/src exists");
    let mut report = Report::default();
    lint_path(&src, &mut report).expect("tree walk succeeds");
    assert!(report.files > 10, "walked only {} files — wrong root?", report.files);
    let rendered: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
    assert!(
        report.findings.is_empty(),
        "rust/src has {} unsuppressed findings:\n{}",
        report.findings.len(),
        rendered.join("\n")
    );
}
