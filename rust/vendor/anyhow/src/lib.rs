//! Minimal vendored subset of the `anyhow` API.
//!
//! The build environment is fully offline (no crates.io registry), so the
//! crate ships this drop-in stand-in as a path dependency. It implements
//! exactly the surface the workspace uses — `Error`, `Result`, `anyhow!`,
//! `bail!`, `ensure!`, and the `Context` extension trait — with the same
//! semantics (context chaining, `From<E: std::error::Error>`, `?`
//! propagation). Swapping in the real crate is a one-line Cargo.toml change.

use std::fmt;

/// Error type: a chain of messages, outermost context first.
pub struct Error {
    /// `chain[0]` is the most recent context; the last entry is the root.
    chain: Vec<String>,
}

impl Error {
    /// Create from anything printable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.first() {
            Some(m) => f.write_str(m),
            None => f.write_str("unknown error"),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            Some((head, rest)) => {
                f.write_str(head)?;
                if !rest.is_empty() {
                    f.write_str("\n\nCaused by:")?;
                    for m in rest {
                        write!(f, "\n    {m}")?;
                    }
                }
                Ok(())
            }
            None => f.write_str("unknown error"),
        }
    }
}

// Mirrors real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket impl coherent
// alongside core's reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Preserve the source chain as context entries.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result<T, anyhow::Error>` with the same default-parameter shape as the
/// real crate, so `Result<T, io::Error>`-style uses still work.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait: attach context to a `Result`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

// Coherent with the impl above because `Error: !std::error::Error` is known
// in-crate and no downstream crate can add that impl (orphan rule).
impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] — same three shapes as the real crate:
/// `anyhow!("literal {x}")`, `anyhow!(displayable_value)`,
/// `anyhow!("fmt {}", args)`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/xyz").context("reading config")?;
        Ok(())
    }

    #[test]
    fn context_chains_and_displays_outermost() {
        let e = io_fail().unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert!(e.chain().count() >= 2);
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn macros_build_errors() {
        let x = 7;
        let e: Error = anyhow!("bad value {x}");
        assert_eq!(e.to_string(), "bad value 7");
        let msg = String::from("owned message");
        assert_eq!(anyhow!(msg).to_string(), "owned message");
        fn b() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(b().unwrap_err().to_string(), "nope 1");
        fn en(v: i32) -> Result<i32> {
            ensure!(v > 0, "v must be positive, got {v}");
            Ok(v)
        }
        assert!(en(1).is_ok());
        assert_eq!(en(-2).unwrap_err().to_string(), "v must be positive, got -2");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<i32> {
            Ok("12x".parse::<i32>()?)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
    }
}
