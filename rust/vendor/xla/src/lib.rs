//! Stub of the `xla` PJRT bindings used by the real-numerics backend.
//!
//! The offline build environment ships neither the `xla` crate nor the
//! `xla_extension` shared library, so this stub provides the exact API
//! surface `runtime/` uses, with two behaviours (DESIGN.md §3 records the
//! policy):
//!
//! * **Host-side types are real.** [`Literal`] stores data and round-trips
//!   `create_from_shape_and_untyped_data` / `copy_raw_to`, so host-tensor
//!   marshalling (and its unit tests) work unchanged.
//! * **Device entry points fail loudly.** [`PjRtClient::cpu`] returns an
//!   error, so anything needing real execution (`Runtime::load`,
//!   `XlaBackend`) fails at construction with a clear message instead of
//!   deep inside a launch. The sim-backend path never touches this crate.
//!
//! Swapping in the real bindings is a one-line change in
//! `rust/Cargo.toml`; no source edits needed.

use std::fmt;
use std::path::Path;

/// Error type (the real crate's `Error` is richer; callers only `{e:?}`).
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} unavailable: this build uses the vendored xla stub \
         (no PJRT runtime in the environment; see DESIGN.md §3)"
    )))
}

/// XLA element types (only the two the AOT contract uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn size_bytes(self) -> usize {
        4
    }
}

/// A host-side literal: shape + raw bytes. Fully functional.
pub struct Literal {
    element_type: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        element_type: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let want = dims.iter().product::<usize>() * element_type.size_bytes();
        if want != data.len() {
            return Err(Error(format!(
                "literal shape {dims:?} wants {want} bytes, got {}",
                data.len()
            )));
        }
        Ok(Literal { element_type, dims: dims.to_vec(), data: data.to_vec() })
    }

    pub fn element_type(&self) -> ElementType {
        self.element_type
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Copy the raw bytes into a typed slice (must match exactly).
    pub fn copy_raw_to<T: Copy>(&self, dst: &mut [T]) -> Result<()> {
        let dst_bytes = std::mem::size_of_val(dst);
        if dst_bytes != self.data.len() {
            return Err(Error(format!(
                "copy_raw_to: literal has {} bytes, destination {dst_bytes}",
                self.data.len()
            )));
        }
        // Size checked above; T is plain data in this contract (f32/i32).
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.data.as_ptr(),
                dst.as_mut_ptr() as *mut u8,
                dst_bytes,
            );
        }
        Ok(())
    }

    /// Tuple decomposition only exists on real PJRT results.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("Literal::decompose_tuple")
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation (opaque in the stub).
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _priv: () }
    }
}

/// A device-resident buffer (never constructible through the stub).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled executable (never constructible through the stub).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// The PJRT client. `cpu()` is the single gate: it fails in the stub, so
/// every real-execution path errors out at construction time.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu (PJRT)")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_f32() {
        let data: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let bytes: &[u8] =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 3], bytes).unwrap();
        let mut back = vec![0f32; 6];
        lit.copy_raw_to(&mut back).unwrap();
        assert_eq!(back, data);
        assert_eq!(lit.dims(), &[2, 3]);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 4])
            .is_err());
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[1], &[0u8; 4]).unwrap();
        let mut too_big = vec![0i32; 2];
        assert!(lit.copy_raw_to(&mut too_big).is_err());
    }

    #[test]
    fn device_paths_fail_loudly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e:?}").contains("stub"));
    }
}
